"""Fig.4-style study: dual-way sparsification under constrained bandwidth.

    PYTHONPATH=src python examples/bandwidth_study.py

Measures real per-iteration wire bytes of ASGD vs DGS (with and without
secondary compression) on the async simulator and models wall-clock at
10 Gbps / 1 Gbps, reproducing the mechanism behind the paper's 5.7x.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

from benchmarks.bench_bandwidth import run  # noqa: E402

if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
