"""End-to-end driver: train a reduced assigned-architecture LM on a host
mesh with the DGS sparse gradient exchange — the mesh-native face of the
paper (DESIGN.md §4).

    PYTHONPATH=src python examples/train_lm_mesh.py --arch mamba2-780m \
        --steps 100 --mode allgather

Runs a ~few-hundred-step training of the reduced config (2 layers,
d_model 256) on an 8-device host mesh (4 data x 2 model), real data
(markov token stream), real optimizer, checkpoints at the end.
This is the deliverable-(b) "train ~100M model for a few hundred steps"
driver at CPU scale; the same builder lowers the full configs on the
production mesh in repro.launch.dryrun.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", default="allgather",
                    choices=["dense", "allgather", "shardedps"])
    ap.add_argument("--density", type=float, default=0.05)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--checkpoint", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax

    from repro.checkpoint import save_checkpoint
    from repro.configs import get_arch
    from repro.configs.shapes import InputShape, input_specs
    from repro.core.distributed import ExchangeConfig
    from repro.data.synthetic import TokenStream
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_train_step, zeros_state
    from repro.models import init_params

    cfg = get_arch(args.arch).reduced()
    mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
    shape = InputShape("example", 128, 16, "train")
    ex_cfg = ExchangeConfig(mode=args.mode, density=args.density,
                            momentum=0.9)
    bundle = build_train_step(cfg, mesh, ex_cfg, lr=args.lr,
                              batch_specs_abstract=input_specs(cfg, shape),
                              remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ex_state = zeros_state(bundle)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=128,
                         batch_size=16, seed=0)
    print(f"training {cfg.name} on mesh {dict(mesh.shape)} "
          f"mode={args.mode} density={args.density}")
    with mesh:
        step = bundle.jit()
        for i in range(args.steps):
            batch = stream.batch(i)
            if cfg.frontend_tokens:
                batch["frontend_embeds"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(1), i),
                    (16, cfg.frontend_tokens, cfg.d_model), cfg.cdtype)
            params, ex_state, loss = step(params, ex_state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"  step {i:4d} loss {float(loss):.4f}")
    save_checkpoint(args.checkpoint, params, step=args.steps,
                    extra={"arch": cfg.name, "mode": args.mode})
    print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
