"""Quickstart: DGS + SAMomentum on a simulated asynchronous PS cluster.

    PYTHONPATH=src python examples/quickstart.py

Trains a small MLP classifier with 8 asynchronous workers at 99% gradient
sparsity and compares against dense ASGD: same accuracy, ~50x less upward
communication.
"""
import jax
import jax.numpy as jnp

from repro.core import async_sim, make_strategy
from repro.data.synthetic import ClassificationTask

task = ClassificationTask(n_features=64, n_classes=10, batch_size=32,
                          noise=0.8, seed=0)


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (64, 64)) * 0.18,
        "b1": jnp.zeros((64,)),
        "w2": jax.random.normal(k2, (64, 10)) * 0.18,
        "b2": jnp.zeros((10,)),
    }


def apply(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def grad_fn(p, batch):
    x, y = batch

    def loss(p):
        lp = jax.nn.log_softmax(apply(p, x))
        return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

    return jax.value_and_grad(loss)(p)


def accuracy(p):
    x, y = task.eval_set(1024)
    return float(jnp.mean(jnp.argmax(apply(p, x), -1) == y))


def main():
    params0 = init_params(jax.random.PRNGKey(0))
    schedule = async_sim.make_schedule(n_workers=8, n_events=600, seed=1,
                                       hetero=0.8)
    for name, kwargs in [
        ("asgd", {}),
        ("dgs", {"density": 0.01, "momentum": 0.7}),
    ]:
        trainer = async_sim.AsyncTrainer(
            strategy=make_strategy(name, **kwargs),
            grad_fn=grad_fn, n_workers=8, lr=0.1)
        final, _, hist = trainer.run(
            params0, schedule, lambda e, k: task.batch(e, worker=k))
        print(f"{name:6s} acc={accuracy(final):.3f} "
              f"up={hist.up_bytes/1e6:6.2f}MB down={hist.down_bytes/1e6:6.2f}MB "
              f"mean_staleness={hist.staleness.mean():.1f}")


if __name__ == "__main__":
    main()
