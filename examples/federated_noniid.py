"""Federated quickstart: heterogeneous clients, non-IID data, real wire.

    PYTHONPATH=src python examples/federated_noniid.py

An in-process cluster (coordinator + client threads over the packed wire
codec) trains an MLP at 95% gradient sparsity under the federated
conditions the single-process simulator cannot express:

* labels sharded non-IID across clients (Dirichlet alpha=0.3),
* 80% per-round partial participation,
* one straggler on a 100 KB/s uplink, one late joiner, one early leaver,
* int8-quantized upward values, secondary-compressed downloads.

Printed up/down numbers are measured wire bytes (headers, scales and
bit-packed values included), not an analytic formula.

For a true multi-process run over TCP sockets:

    PYTHONPATH=src python -m repro.launch.cluster --clients 4 --alpha 0.3

and to range-partition the parameter server across S coordinator shards
(DESIGN.md §12 — bit-identical results, per-shard memory/commit load):

    PYTHONPATH=src python -m repro.launch.cluster --clients 4 --shards 2

(client processes are spawned automatically; a manually launched client
reaches a sharded coordinator with ``--role client --ports p0,p1,...``,
one port per shard.)
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.cluster import run_inprocess
from repro.cluster.scenarios import NonIIDClassification, hetero_plans
from repro.core import make_strategy
from repro.data.synthetic import ClassificationTask

N_CLIENTS, N_ROUNDS = 6, 30

task = ClassificationTask(n_features=64, n_classes=10, batch_size=32,
                          noise=0.8, seed=0)
data = NonIIDClassification(task=task, alpha=0.3, n_clients=N_CLIENTS)


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (64, 64)) * 0.18,
        "b1": jnp.zeros((64,)),
        "w2": jax.random.normal(k2, (64, 10)) * 0.18,
        "b2": jnp.zeros((10,)),
    }


def apply(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def grad_fn(p, batch):
    x, y = batch

    def loss(p):
        lp = jax.nn.log_softmax(apply(p, x))
        return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

    return jax.value_and_grad(loss)(p)


def accuracy(p):
    x, y = data.eval_set(1024)
    return float(jnp.mean(jnp.argmax(apply(p, x), -1) == y))


def main():
    plans = hetero_plans(N_CLIENTS, N_ROUNDS, hetero=0.8, seed=1,
                         participation=0.8, late_join=1, early_leave=1)
    # client 0 is additionally stuck behind a 100 KB/s uplink
    plans[0] = dataclasses.replace(plans[0], bandwidth=100e3)

    final, hist = run_inprocess(
        make_strategy("dgs", density=0.05, momentum=0.7, quantize="int8"),
        grad_fn,
        init_params(jax.random.PRNGKey(0)),
        lambda e, k: data.batch(int(e), int(k) % N_CLIENTS),
        plans=plans,
        lr=0.1,
        secondary_density=0.05,
        inject_faults=True,
    )
    n = max(1, len(hist.losses))
    print(f"{n} federated rounds served "
          f"(partial participation thins {N_CLIENTS * N_ROUNDS} slots)")
    print(f"loss {hist.losses[:5].mean():.3f} -> {hist.losses[-5:].mean():.3f}"
          f"  acc={accuracy(final):.3f}")
    print(f"measured wire: up={hist.up_bytes / 1e3:.1f}KB "
          f"({hist.up_bytes / n:.0f}B/round)  "
          f"down={hist.down_bytes / 1e3:.1f}KB "
          f"({hist.down_bytes / n:.0f}B/round)")
    print(f"mean staleness {hist.staleness.mean():.1f} events")


if __name__ == "__main__":
    main()
