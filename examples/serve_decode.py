"""Decode-while-training: a live inference replica fed by sparse diffs.

    PYTHONPATH=src python examples/serve_decode.py

One process, the whole serve story (DESIGN.md §13): an async DGS
training run drives the parameter server while an inference replica —
subscribed over the in-proc transport — answers a batched eval workload
between diff applies.  The replica never blocks training: the
coordinator coalesces every committed update into the replica's
residual cursor and ships ONE re-sparsified ARENA frame per pull, so
the replica's accuracy climbs *during* the run, lagging the server by a
bounded number of versions.  At quiesce the replica SYNCs and its model
is bit-identical to the server's.

The coordinator also appends sparse delta-checkpoints of the live
arena; the demo restores the chain at the end and checks it too is
bit-exact.  For the multi-process TCP version of this demo run
``python -m repro.launch.serve --smoke``; for the standalone mesh
prefill/decode loop (KV/MLA/SSM caches) run
``python -m repro.launch.serve --role decode``.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_delta_checkpoint
from repro.cluster import run_inprocess
from repro.core import async_sim, make_strategy
from repro.core.paramspace import ParamSpace
from repro.data.synthetic import ClassificationTask


def main():
    task = ClassificationTask(n_features=32, n_classes=8, batch_size=32,
                              noise=0.6, seed=0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params0 = {"w1": jax.random.normal(k1, (32, 32)) * 0.2,
               "b1": jnp.zeros((32,)),
               "w2": jax.random.normal(k2, (32, 8)) * 0.2,
               "b2": jnp.zeros((8,))}
    x_eval, y_eval = task.eval_set(256)

    @jax.jit
    def logits_fn(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    def grad_fn(p, batch):
        x, y = batch

        def loss(p):
            lp = jax.nn.log_softmax(logits_fn(p, x))
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(p)

    def batch_fn(e, k):
        return task.batch(int(e), int(k))

    trajectory = []

    def decode_fn(params, step):
        # the replica's "traffic": one batched forward per diff window,
        # on whatever model version the last applied diff produced
        acc = float(jnp.mean(
            jnp.argmax(logits_fn(params, x_eval), -1) == y_eval))
        trajectory.append(acc)
        if step % 8 == 0:
            print(f"  [replica] decode {step:>3}  acc={acc:.3f}")

    sched = async_sim.make_schedule(4, 120, seed=0, hetero=0.8)
    strat = make_strategy("dgs", density=0.1, momentum=0.7)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("[train] 4 workers x 120 events, dgs d=0.1; "
              "1 replica at push-density 0.25, max_staleness 4")
        final, hist = run_inprocess(
            strat, grad_fn, params0, batch_fn,
            schedule=sched, lr=0.1, secondary_density=0.2,
            n_replicas=1, push_density=0.25, max_staleness=4,
            replica_decode_fn=decode_fn,
            ckpt_dir=ckpt_dir, ckpt_every=16)

        arena = np.asarray(ParamSpace.from_tree(params0).pack(final))
        rep = hist.metrics["replicas"][0]
        ck, ck_version, _ = load_delta_checkpoint(ckpt_dir)

    print(f"[train]   loss {hist.losses[:3].mean():.4f} -> "
          f"{hist.losses[-3:].mean():.4f}  "
          f"({len(hist.losses)} events)")
    print(f"[replica] acc  {trajectory[0]:.3f} -> {trajectory[-1]:.3f}  "
          f"over {rep['decodes']} decode boundaries, "
          f"{rep['diffs']} diffs, {rep['bytes_in']} push bytes")
    print(f"[replica] final model bit-identical to server: "
          f"{np.array_equal(rep['arena'], arena)} "
          f"(version {rep['version']})")
    print(f"[ckpt]    delta-chain restore bit-identical: "
          f"{np.array_equal(ck, arena)} (version {ck_version})")
    assert np.array_equal(rep["arena"], arena)
    assert np.array_equal(ck, arena)


if __name__ == "__main__":
    main()
