"""Batched serving example: prefill + decode with KV/MLA/SSM caches.

    PYTHONPATH=src python examples/serve_decode.py --arch minicpm3-4b

Demonstrates the serve path for three cache disciplines: GQA KV cache,
MiniCPM3's compressed MLA latent cache, and Mamba2's O(1) recurrent state —
on the reduced configs.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.launch import mesh as mesh_lib
    from repro.models import decode_step, init_params, prefill

    cfg = get_arch(args.arch).reduced()
    mesh = mesh_lib.make_mesh((1, jax.device_count()), ("data", "model"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    fe = None
    if cfg.frontend_tokens:
        fe = jax.random.normal(jax.random.PRNGKey(2),
                               (args.batch, cfg.frontend_tokens,
                                cfg.d_model), cfg.cdtype)

    pf = jax.jit(lambda p, t: prefill(p, t, cfg, frontend_embeds=fe,
                                      max_len=max_len))
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))

    import time
    with mesh:
        t0 = time.perf_counter()
        logits, caches, _ = pf(params, prompt)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        toks = [jnp.argmax(logits[:, -1], -1)]
        t0 = time.perf_counter()
        for t in range(args.gen - 1):
            logits, caches = dec(params, caches, toks[-1][:, None],
                                 jnp.int32(args.prompt_len + t))
            toks.append(jnp.argmax(logits[:, 0], -1))
        jax.block_until_ready(toks[-1])
        t_decode = time.perf_counter() - t0
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(caches))
    print(f"arch={cfg.name}  prefill={t_prefill*1e3:.1f}ms  "
          f"decode={t_decode/max(1, args.gen-1)*1e3:.1f}ms/tok  "
          f"cache={cache_bytes/2**20:.2f}MiB")
    out = jnp.stack(toks, axis=1)
    for b in range(min(2, args.batch)):
        print(f"  seq{b}:", out[b].tolist())


if __name__ == "__main__":
    main()
