"""Render the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json and experiments/perf/*.json.

    PYTHONPATH=src python scripts/make_experiments.py > experiments/tables.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline_table import load_rows, markdown  # noqa: E402


def fmt_bytes(x):
    if x is None:
        return "?"
    return f"{x/2**30:.2f} GiB"


def dryrun_summary(rows):
    lines = ["| arch | shape | mesh | compile OK | args/dev | temp/dev | "
             "collectives |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        colls = r.get("collective_counts", {})
        cstr = " ".join(f"{k}:{v}" for k, v in sorted(colls.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | yes "
            f"| {fmt_bytes(r.get('argument_bytes'))} "
            f"| {fmt_bytes(r.get('temp_bytes'))} | {cstr} |")
    return "\n".join(lines)


def perf_table(base_rows, perf_dir):
    """Hillclimb comparisons keyed on (arch, shape)."""
    perf = load_rows(perf_dir)
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in base_rows}
    lines = ["| pair | variant | compute ms | memory ms | collective ms | "
             "dominant | Δdominant vs baseline |",
             "|---|---|---|---|---|---|---|"]
    for fn in sorted(os.listdir(perf_dir)) if os.path.isdir(perf_dir) else []:
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(perf_dir, fn)) as f:
            r = json.load(f)
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        variant = fn.replace(".json", "").replace(
            f"{r['arch']}_{r['shape']}_{r['mesh']}", "").strip("_") or \
            "baseline"
        if b:
            dom = b["dominant"]
            key = {"compute": "compute_s", "memory": "memory_s",
                   "collective": "collective_s"}[dom]
            delta = (r[key] - b[key]) / b[key] * 100
            dstr = f"{delta:+.1f}% ({dom})"
        else:
            dstr = "?"
        lines.append(
            f"| {r['arch']} x {r['shape']} | {variant} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['dominant']} | {dstr} |")
    return "\n".join(lines)


def main():
    rows = load_rows("experiments/dryrun")
    print("## Generated: §Dry-run summary\n")
    print(dryrun_summary(rows))
    print("\n## Generated: §Roofline table\n")
    print(markdown(rows))
    print("\n## Generated: §Perf comparisons\n")
    print(perf_table(rows, "experiments/perf"))


if __name__ == "__main__":
    main()
