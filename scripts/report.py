#!/usr/bin/env python
"""Render a flight-recorder run directory into a human-readable report.

    python scripts/report.py RUN_DIR [--out report.md] [--check]

``RUN_DIR`` is wherever a :class:`repro.telemetry.Recorder` flushed its
artifacts (``--trace-dir`` on the cluster launcher, or any test/bench that
passed ``recorder=Recorder(dir)``).  The report is plain markdown (renders
in a terminal as-is): run summary, staleness distribution, up/down frame
size histograms, the bytes-vs-loss curve, a per-stage wall-clock breakdown
aggregated from the Chrome-trace spans, a per-client fault/retry table
from the counters record, and — for sharded runs — a shard-balance table
from the ``shard/{i}/...`` counters.

``--check`` is the CI mode: exit nonzero unless both artifacts exist,
parse, and the report contains the staleness and bytes sections — the
telemetry smoke gate in scripts/ci.sh.

Stdlib only: no repro imports, so the report renders anywhere.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

TRACE_FILE = "trace.json"
EVENTS_FILE = "events.jsonl"
BAR_WIDTH = 40


def load_run(run_dir: pathlib.Path):
    """Parse (trace_events, jsonl_records); raises on missing/corrupt."""
    trace = json.loads((run_dir / TRACE_FILE).read_text())
    if "traceEvents" not in trace:
        raise ValueError(f"{TRACE_FILE}: no traceEvents key")
    events = []
    for i, line in enumerate((run_dir / EVENTS_FILE).read_text().splitlines()):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError as exc:
            raise ValueError(f"{EVENTS_FILE}:{i + 1}: {exc}") from exc
    return trace["traceEvents"], events


def _last(events, kind):
    found = None
    for e in events:
        if e.get("kind") == kind:
            found = e
    return found


def render_hist(hist: dict, title: str) -> list[str]:
    """One ``{"bins": [...], "counts": [...]}`` histogram as an ascii
    bar chart."""
    counts = hist.get("counts", [])
    bins = hist.get("bins", [])
    total = sum(counts)
    out = [f"### {title}", ""]
    if not total:
        out += ["(empty)", ""]
        return out
    peak = max(counts)
    for label, c in zip(bins, counts):
        bar = "#" * max(1 if c else 0, round(c / peak * BAR_WIDTH))
        out.append(f"    {label:>16}  {c:>8}  {bar}")
    out += ["", f"    total: {total}", ""]
    return out


def render_summary(summary: dict) -> list[str]:
    out = ["## Run summary", ""]
    rows = [("runner", summary.get("runner")),
            ("events", summary.get("n_events")),
            ("up bytes", summary.get("up_bytes")),
            ("down bytes", summary.get("down_bytes")),
            ("first loss", summary.get("loss_first")),
            ("last loss", summary.get("loss_last"))]
    for k, v in rows:
        if v is not None:
            out.append(f"- **{k}**: {v}")
    metrics = summary.get("metrics") or {}
    counters = metrics.get("counters") or {}
    # route overflow gets its own line (it is a health gate, not traffic):
    # nonzero means a shard route / bucket capacity slot dropped entries
    overflow = counters.get("route_overflow", metrics.get("route_overflow"))
    if overflow is not None:
        out.append(f"- **route overflow**: {int(overflow)}")
    run_level = {k: v for k, v in counters.items()
                 if "/" not in k and k != "route_overflow"}
    if run_level:
        out.append("- **messages**: " + ", ".join(
            f"{k}={int(v)}" for k, v in sorted(run_level.items())))
    out.append("")
    return out


def render_bytes_vs_loss(events) -> list[str]:
    """The paper's central trade-off, from the progress/eval stream."""
    points = []
    for e in events:
        if e.get("kind") == "progress":
            points.append((e.get("up_bytes", 0) + e.get("down_bytes", 0),
                           e.get("event"), e.get("loss"), None))
        elif e.get("kind") == "eval":
            points.append((None, e.get("event"), None, e.get("metric")))
    if not points:
        return []
    out = ["## Bytes vs loss", "",
           "| event | cumulative bytes | loss | eval |",
           "|---:|---:|---:|---:|"]
    # subsample long runs to ~20 rows; always keep the last point
    keep = max(1, len(points) // 20)
    sampled = points[::keep]
    if sampled[-1] is not points[-1]:
        sampled.append(points[-1])
    for nbytes, event, loss, metric in sampled:
        out.append("| {} | {} | {} | {} |".format(
            event if event is not None else "",
            nbytes if nbytes is not None else "",
            f"{loss:.4f}" if loss is not None else "",
            f"{metric:.4f}" if isinstance(metric, float) else ""))
    out.append("")
    return out


def render_stage_breakdown(trace_events) -> list[str]:
    """Aggregate complete ("ph": "X") spans by name: where the host
    wall-clock went."""
    agg: dict[str, list[float]] = {}
    for ev in trace_events:
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev["name"], []).append(float(ev.get("dur", 0.0)))
    if not agg:
        return []
    out = ["## Per-stage time breakdown", "",
           "| stage | calls | total ms | mean us |",
           "|:--|---:|---:|---:|"]
    for name, durs in sorted(agg.items(), key=lambda kv: -sum(kv[1])):
        total_us = sum(durs)
        out.append(f"| {name} | {len(durs)} | {total_us / 1e3:.2f} "
                   f"| {total_us / len(durs):.1f} |")
    out.append("")
    return out


def render_clients(events) -> list[str]:
    """Per-client table from the flushed counters record."""
    counters = (_last(events, "counters") or {}).get("counters", {})
    per_client: dict[str, dict[str, float]] = {}
    for name, v in counters.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "client":
            per_client.setdefault(parts[1], {})[parts[2]] = v
    if not per_client:
        return []
    cols = sorted({c for fields in per_client.values() for c in fields})
    out = ["## Per-client activity", "",
           "| client | " + " | ".join(cols) + " |",
           "|---:|" + "---:|" * len(cols)]
    for cid in sorted(per_client, key=lambda c: int(c) if c.isdigit() else 0):
        fields = per_client[cid]
        cells = []
        for c in cols:
            v = fields.get(c, 0)
            cells.append(f"{v:.3f}" if isinstance(v, float)
                         and not float(v).is_integer() else f"{int(v)}")
        out.append(f"| {cid} | " + " | ".join(cells) + " |")
    out.append("")
    return out


def render_shards(events) -> list[str]:
    """Shard-balance table from the ``shard/{i}/...`` counters a sharded
    coordinator run flushes (DESIGN.md §12): arena elements, events, and
    up/down bytes per range-partitioned shard."""
    counters = (_last(events, "counters") or {}).get("counters", {})
    per_shard: dict[str, dict[str, float]] = {}
    for name, v in counters.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "shard":
            per_shard.setdefault(parts[1], {})[parts[2]] = v
    if len(per_shard) < 2:  # single-shard runs don't need a balance table
        return []
    cols = sorted({c for fields in per_shard.values() for c in fields})
    out = ["## Shard balance", "",
           "| shard | " + " | ".join(cols) + " |",
           "|---:|" + "---:|" * len(cols)]
    for sid in sorted(per_shard, key=lambda s: int(s) if s.isdigit() else 0):
        fields = per_shard[sid]
        cells = []
        for c in cols:
            v = fields.get(c, 0)
            cells.append(f"{v:.3f}" if isinstance(v, float)
                         and not float(v).is_integer() else f"{int(v)}")
        out.append(f"| {sid} | " + " | ".join(cells) + " |")
    out.append("")
    return out


def render_replicas(events) -> list[str]:
    """Replica-fleet table from the ``sub/{i}/...`` counters a
    serve-enabled coordinator flushes (DESIGN.md §13): pushes, push
    bytes, version lag, and final version per inference replica."""
    counters = (_last(events, "counters") or {}).get("counters", {})
    per_sub: dict[str, dict[str, float]] = {}
    for name, v in counters.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "sub":
            per_sub.setdefault(parts[1], {})[parts[2]] = v
    if not per_sub:
        return []
    cols = sorted({c for fields in per_sub.values() for c in fields})
    out = ["## Replica fleet", "",
           "| replica | " + " | ".join(cols) + " |",
           "|---:|" + "---:|" * len(cols)]
    for rid in sorted(per_sub, key=lambda r: int(r) if r.isdigit() else 0):
        fields = per_sub[rid]
        cells = []
        for c in cols:
            v = fields.get(c, 0)
            cells.append(f"{v:.3f}" if isinstance(v, float)
                         and not float(v).is_integer() else f"{int(v)}")
        out.append(f"| {rid} | " + " | ".join(cells) + " |")
    out.append("")
    return out


def render_report(run_dir: pathlib.Path) -> str:
    trace_events, events = load_run(run_dir)
    summary = _last(events, "run_summary") or {}
    lines = [f"# Flight-recorder report: {run_dir}", ""]
    lines += render_summary(summary)
    for key, title in (("staleness_hist", "Staleness distribution"),
                       ("up_bytes_hist", "Up frame bytes"),
                       ("down_bytes_hist", "Down frame bytes")):
        if summary.get(key):
            lines += render_hist(summary[key], title)
    metrics = summary.get("metrics") or {}
    for key, title in (("up_nnz_hist", "Up message nnz"),
                       ("down_nnz_hist", "Down message nnz"),
                       ("update_mag_hist", "Update magnitude |G|^2")):
        if metrics.get(key):
            lines += render_hist(metrics[key], title)
    lines += render_bytes_vs_loss(events)
    lines += render_stage_breakdown(trace_events)
    lines += render_clients(events)
    lines += render_shards(events)
    lines += render_replicas(events)
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("run_dir", type=pathlib.Path,
                    help="directory holding trace.json + events.jsonl")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the markdown here instead of stdout")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: exit nonzero unless artifacts parse and "
                         "the staleness + bytes sections rendered")
    ap.add_argument("--expect-shards", action="store_true",
                    help="with --check: also require the shard-balance "
                         "table (sharded coordinator runs)")
    ap.add_argument("--expect-replicas", type=int, default=None,
                    metavar="N",
                    help="with --check: also require the replica-fleet "
                         "table with N replica rows, each carrying pushes "
                         "+ push_bytes + lag_max counters (serve runs)")
    args = ap.parse_args(argv)

    try:
        report = render_report(args.run_dir)
    except (OSError, ValueError, json.JSONDecodeError) as exc:
        print(f"report: cannot load {args.run_dir}: {exc}", file=sys.stderr)
        return 1

    if args.out:
        args.out.write_text(report + "\n")
        print(f"wrote {args.out}")
    else:
        print(report)

    if args.check:
        missing = [title for title in
                   ("Staleness distribution", "Up frame bytes",
                    "Down frame bytes")
                   if f"### {title}" not in report]
        if args.expect_shards and "## Shard balance" not in report:
            missing.append("Shard balance")
        if args.expect_replicas is not None:
            if "## Replica fleet" not in report:
                missing.append("Replica fleet")
            else:
                _, events = load_run(args.run_dir)
                counters = (_last(events, "counters") or {}) \
                    .get("counters", {})
                for i in range(args.expect_replicas):
                    for col in ("pushes", "push_bytes", "lag_max"):
                        if f"sub/{i}/{col}" not in counters:
                            missing.append(f"sub/{i}/{col}")
        if missing:
            print(f"report --check: missing sections: {missing}",
                  file=sys.stderr)
            return 1
        print("report --check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
