#!/usr/bin/env bash
# Fast CI path: lint (when ruff is installed), fail on the first broken
# test, then the fused-arena/scan-runner hot-path smoke, then the
# timeout-guarded multiprocess socket smoke (the TCP cluster path must not
# rot off-TPU: coordinator + 2 client processes over real sockets).
# Full tier-1 sweep (no -x) is what .github/workflows/ci.yml runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests
else
  echo "ruff not installed; skipping lint (pip install -r requirements-dev.txt)"
fi
python -m pytest -q -x "$@"
# fused arena event loop + lax.scan runner: must beat per-leaf / stay
# byte-parity-exact (asserts inside --smoke)
timeout 600 python -m benchmarks.bench_scalability --smoke
timeout 300 python -m repro.launch.cluster --smoke
