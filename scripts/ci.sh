#!/usr/bin/env bash
# Fast CI path: fail on the first broken test, quiet output, then the
# timeout-guarded multiprocess socket smoke (the TCP cluster path must not
# rot off-TPU: coordinator + 2 client processes over real sockets).
# Full tier-1 sweep (no -x) is what .github/workflows/ci.yml runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q -x "$@"
timeout 300 python -m repro.launch.cluster --smoke
