#!/usr/bin/env bash
# Fast CI path: lint (when ruff is installed), fail on the first broken
# test, then the fused-arena/scan-runner hot-path smoke, then the
# timeout-guarded multiprocess socket smoke (the TCP cluster path must not
# rot off-TPU: coordinator + 2 client processes over real sockets).
# Full tier-1 sweep (no -x) is what .github/workflows/ci.yml runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks scripts
else
  echo "ruff not installed; skipping lint (pip install -r requirements-dev.txt)"
fi
python -m pytest -q -x "$@"
# fused arena event loop + lax.scan runner + batched event loop: must
# beat per-leaf / stay byte-parity-exact / beat serial by >= 1.2x
# (asserts inside --smoke, which also writes BENCH_scalability.json)
timeout 600 python -m benchmarks.bench_scalability --smoke
test -s BENCH_scalability.json || {
  echo "FAIL: BENCH_scalability.json not written"; exit 1; }
# telemetry smoke: the same socket smoke with the flight recorder on;
# the report gate asserts trace.json + events.jsonl were written, parse,
# and carry the staleness + bytes histograms
rm -rf .ci_telemetry
timeout 300 python -m repro.launch.cluster --smoke --trace-dir .ci_telemetry
python scripts/report.py .ci_telemetry --check >/dev/null
# sharded TCP smoke: 2 range-partitioned coordinator shards over real
# sockets; --smoke --shards 2 first runs a 1-shard reference and asserts
# the sharded losses + final params are bit-identical to it, and the
# report gate additionally checks the shard/{i} counters rendered
rm -rf .ci_telemetry_sharded
timeout 300 python -m repro.launch.cluster --smoke --shards 2 \
  --trace-dir .ci_telemetry_sharded
python scripts/report.py .ci_telemetry_sharded --check --expect-shards \
  >/dev/null
