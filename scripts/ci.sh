#!/usr/bin/env bash
# Fast CI path: fail on the first broken test, quiet output.
# Full tier-1 sweep (no -x) is what .github/workflows/ci.yml runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -x "$@"
