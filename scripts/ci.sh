#!/usr/bin/env bash
# Fast CI path: lint (when ruff is installed), fail on the first broken
# test, then the fused-arena/scan-runner hot-path smoke, then the
# timeout-guarded multiprocess socket smoke (the TCP cluster path must not
# rot off-TPU: coordinator + 2 client processes over real sockets).
# Full tier-1 sweep (no -x) is what .github/workflows/ci.yml runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests
else
  echo "ruff not installed; skipping lint (pip install -r requirements-dev.txt)"
fi
python -m pytest -q -x "$@"
# fused arena event loop + lax.scan runner + batched event loop: must
# beat per-leaf / stay byte-parity-exact / beat serial by >= 1.2x
# (asserts inside --smoke, which also writes BENCH_scalability.json)
timeout 600 python -m benchmarks.bench_scalability --smoke
test -s BENCH_scalability.json || {
  echo "FAIL: BENCH_scalability.json not written"; exit 1; }
timeout 300 python -m repro.launch.cluster --smoke
