#!/usr/bin/env bash
# Staged CI driver.  Usage:
#
#   scripts/ci.sh [lint|tests|smoke|all] [pytest args...]
#
# * lint  — ruff (skipped with a note when not installed)
# * tests — first-failure tier-1 sweep (extra args go to pytest)
# * smoke — the timeout-guarded system smokes: fused-arena bench, TCP
#           cluster, sharded TCP cluster, and the serve fleet (training
#           coordinator + 1 trainer + 2 TCP inference replicas; asserts
#           replicas converge to the server model bit-for-bit and the
#           delta-checkpoint restore matches the live arena).  Report
#           markdown for every smoke lands in .ci_reports/ (uploaded as
#           workflow artifacts); scratch telemetry dirs are removed by
#           the EXIT trap even when a smoke times out or dies mid-run.
# * all   — the default: lint, tests, smoke.
#
# .github/workflows/ci.yml fans these stages out as parallel jobs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE="${1:-all}"
if [ $# -gt 0 ]; then shift; fi

REPORTS=.ci_reports
SCRATCH=(.ci_telemetry .ci_telemetry_sharded .ci_telemetry_mesh
         .ci_serve_smoke)

cleanup() {
  rm -rf "${SCRATCH[@]}"
}
trap cleanup EXIT

run_lint() {
  if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks scripts
  else
    echo "ruff not installed; skipping lint (pip install -r requirements-dev.txt)"
  fi
}

run_tests() {
  python -m pytest -q -x "$@"
}

run_smoke() {
  mkdir -p "$REPORTS"
  # fused arena event loop + lax.scan runner + batched event loop: must
  # beat per-leaf / stay byte-parity-exact / beat serial by >= 1.2x
  # (asserts inside --smoke, which also writes BENCH_scalability.json)
  timeout 600 python -m benchmarks.bench_scalability --smoke
  test -s BENCH_scalability.json || {
    echo "FAIL: BENCH_scalability.json not written"; exit 1; }

  # telemetry smoke: the socket smoke with the flight recorder on; the
  # report gate asserts trace.json + events.jsonl were written, parse,
  # and carry the staleness + bytes histograms
  rm -rf .ci_telemetry
  timeout 300 python -m repro.launch.cluster --smoke --trace-dir .ci_telemetry
  python scripts/report.py .ci_telemetry --check \
    --out "$REPORTS/cluster_smoke.md" >/dev/null

  # sharded TCP smoke: 2 range-partitioned coordinator shards over real
  # sockets; --smoke --shards 2 first runs a 1-shard reference and asserts
  # the sharded losses + final params are bit-identical to it, and the
  # report gate additionally checks the shard/{i} counters rendered
  rm -rf .ci_telemetry_sharded
  timeout 300 python -m repro.launch.cluster --smoke --shards 2 \
    --trace-dir .ci_telemetry_sharded
  python scripts/report.py .ci_telemetry_sharded --check --expect-shards \
    --out "$REPORTS/cluster_sharded_smoke.md" >/dev/null

  # device-mesh shard smoke (DESIGN.md §14), under 4 forced host devices
  # so the alltoallv parity tests' device config is the one CI runs:
  # (a) the mesh bench gate — bit-parity vs the flat batched server,
  # mesh runtime vs the S-thread runtime at S=4 — which must land the
  # mesh_sharded rows in BENCH_scalability.json; (b) a mesh TCP smoke
  # (ONE port, in-graph shards) asserting bit-identity to the 1-shard
  # reference INCLUDING measured wire bytes, with the shard-balance
  # table + route-overflow line rendered from the emitted trace
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    timeout 600 python -m benchmarks.bench_scalability --smoke-mesh
  grep -q "mesh_sharded/S4" BENCH_scalability.json || {
    echo "FAIL: mesh_sharded rows missing from BENCH_scalability.json"
    exit 1; }
  rm -rf .ci_telemetry_mesh
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    timeout 300 python -m repro.launch.cluster --smoke --mesh-shards 2 \
    --trace-dir .ci_telemetry_mesh
  python scripts/report.py .ci_telemetry_mesh --check --expect-shards \
    --out "$REPORTS/cluster_mesh_smoke.md" >/dev/null

  # serve smoke: coordinator + 1 training client + 2 TCP inference
  # replica processes; --smoke asserts every replica's final params are
  # bit-identical to the server model at quiesce and that restoring the
  # delta-checkpoint chain reproduces the live arena bit for bit.  The
  # report gate then requires the replica-fleet table (per-replica lag +
  # push-bytes counters) rendered from the emitted trace.
  rm -rf .ci_serve_smoke
  timeout 300 python -m repro.launch.serve --smoke \
    --trace-dir .ci_serve_smoke/trace --ckpt-dir .ci_serve_smoke/ckpt \
    --out-dir .ci_serve_smoke/out
  python scripts/report.py .ci_serve_smoke/trace --check \
    --expect-replicas 2 --out "$REPORTS/serve_smoke.md" >/dev/null
}

case "$STAGE" in
  lint)  run_lint ;;
  tests) run_tests "$@" ;;
  smoke) run_smoke ;;
  all)   run_lint; run_tests "$@"; run_smoke ;;
  *)     echo "usage: scripts/ci.sh [lint|tests|smoke|all] [pytest args...]"
         exit 2 ;;
esac
