"""Deterministic synthetic data pipelines.

Three generators, all stateless (step -> batch), reproducible, and shardable
along the batch axis:

* ``TokenStream``     — markov-chain token sequences for LM training.  A
                        fixed random transition matrix gives the stream
                        learnable structure (loss decreases measurably within
                        a few hundred steps, unlike uniform noise).
* ``ClassificationTask`` — gaussian-blobs classification (the CIFAR stand-in
                        for the paper's convergence experiments).
* ``SequenceCopyTask``  — delayed-copy sequence task (the AN4/LSTM stand-in).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 8   # out-degree of the markov chain

    def _transition(self):
        rng = np.random.default_rng(self.seed)
        nxt = rng.integers(0, self.vocab_size,
                           (self.vocab_size, self.branching))
        return jnp.asarray(nxt, jnp.int32)

    def batch(self, step: int, *, batch_size: int | None = None):
        B = batch_size or self.batch_size
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        nxt = self._transition()

        k0, k1 = jax.random.split(key)
        tok0 = jax.random.randint(k0, (B,), 0, self.vocab_size)
        branches = jax.random.randint(k1, (B, self.seq_len - 1), 0,
                                      self.branching)

        def gen(tok, br):
            return nxt[tok, br], nxt[tok, br]

        def seq(t0, brs):
            _, toks = jax.lax.scan(gen, t0, brs)
            return jnp.concatenate([t0[None], toks])

        tokens = jax.vmap(seq)(tok0, branches)
        return {"tokens": tokens}


@dataclasses.dataclass(frozen=True)
class ClassificationTask:
    n_features: int = 64
    n_classes: int = 10
    batch_size: int = 32
    seed: int = 0
    noise: float = 0.6

    def centers(self):
        key = jax.random.PRNGKey(self.seed + 999)
        return jax.random.normal(key, (self.n_classes, self.n_features))

    def batch(self, step: int, worker: int = 0):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), worker)
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, (self.batch_size,), 0, self.n_classes)
        x = self.centers()[y] + self.noise * jax.random.normal(
            kx, (self.batch_size, self.n_features))
        return x, y

    def eval_set(self, n: int = 512):
        key = jax.random.PRNGKey(self.seed + 31337)
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, (n,), 0, self.n_classes)
        x = self.centers()[y] + self.noise * jax.random.normal(
            kx, (n, self.n_features))
        return x, y


@dataclasses.dataclass(frozen=True)
class SequenceCopyTask:
    """Emit a marker, a payload of ``copy_len`` symbols, then expect the
    payload to be reproduced after a delay — an LSTM-friendly memory task."""

    vocab_size: int = 32
    copy_len: int = 8
    delay: int = 8
    batch_size: int = 16
    seed: int = 0

    @property
    def seq_len(self):
        return 1 + self.copy_len + self.delay + self.copy_len

    def batch(self, step: int, worker: int = 0):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), worker)
        payload = jax.random.randint(
            key, (self.batch_size, self.copy_len), 2, self.vocab_size)
        marker = jnp.ones((self.batch_size, 1), jnp.int32)
        blank = jnp.zeros((self.batch_size, self.delay), jnp.int32)
        inputs = jnp.concatenate(
            [marker, payload, blank,
             jnp.zeros((self.batch_size, self.copy_len), jnp.int32)], axis=1)
        # targets: payload at the tail positions, -1 (ignore) elsewhere
        ignore = -jnp.ones(
            (self.batch_size, 1 + self.copy_len + self.delay), jnp.int32)
        targets = jnp.concatenate([ignore, payload], axis=1)
        return inputs, targets
