"""Fused wire quantize+pack — the codec's value pipeline as one program.

The legacy ARENA encoder (cluster/wire.py) looped python-side over the
message's per-tensor segments: one jitted ``quantize_parts`` call per
segment plus one host transfer per segment for codes and one for the
scale.  This module fuses the whole value pipeline — per-segment scales,
wire codes, the bit-packed value block, the dequantized ("shipped")
values, and the size-narrowed index block — into ONE jitted program per
``(mode, seg, size)`` specialization, so ``wire.pack_from_arena`` makes a
constant ~3 host transfers per message regardless of how many tensors the
arena message spans.  The message values can be (and in the batched
runtime are) views into the flat parameter arena: nothing here copies
them before the program runs.

Scale arithmetic is ``sparsify.quantize_parts`` VERBATIM (the same jitted
sub-program per segment), which is what makes the packed frames bit-equal
to the legacy per-segment encoder; the Pallas kernels recompute the
elementwise code/dequantize ops (round/clip/sign/multiply) from the
broadcast scales — elementwise IEEE ops on identical inputs, so the TPU
path is bit-equal by construction too.

Layout convention matches kernels/ops.py: flat vectors pad to
``(ROWS, LANE)`` f32 tiles; the tern packer consumes ``(m, 4*LANE)`` sign
codes and emits ``(m, LANE)`` bytes — four 2-bit two's-complement codes
per byte, little-end first, the codec's ``_pack_tern`` order.

Off-TPU the public entry point uses the identical-arithmetic XLA ops
(interpret-mode Pallas would serialize the grid loop in Python — the
repo-wide pitfall); the Pallas path compiles on TPU and is exercised in
tests via ``interpret=True``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparsify import quantize_parts

LANE = 128
ROWS = 8       # f32 tile rows per grid step


# ---------------------------------------------------------------------------
# Pallas kernels (TPU fast path; interpret=True in tests)
# ---------------------------------------------------------------------------

def _bf16_kernel(x_ref, code_ref, dq_ref):
    b = x_ref[...].astype(jnp.bfloat16)
    code_ref[...] = jax.lax.bitcast_convert_type(b, jnp.uint16)
    dq_ref[...] = b.astype(jnp.float32)


def _int8_kernel(x_ref, s_ref, code_ref, dq_ref):
    x = x_ref[...]
    s = s_ref[...]
    q = jnp.clip(jnp.round(x / s), -127, 127)
    code_ref[...] = q.astype(jnp.int8)
    dq_ref[...] = (q * s).astype(jnp.float32)


def _tern_kernel(x_ref, s_ref, code_ref, dq_ref):
    s = jnp.sign(x_ref[...])
    code_ref[...] = s.astype(jnp.int8)
    dq_ref[...] = (s * s_ref[...]).astype(jnp.float32)


def _tern_pack_kernel(c_ref, o_ref):
    # (1, 4*LANE) sign codes -> (1, LANE) bytes; byte t packs codes
    # 4t..4t+3 as little-end 2-bit two's-complement fields
    u = (c_ref[...].astype(jnp.int32) & 3).reshape(LANE, 4)
    o_ref[...] = (u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4)
                  | (u[:, 3] << 6)).astype(jnp.uint8).reshape(1, LANE)


def _tiles(x, fill=0.0):
    """Pad a flat vector to full (ROWS, LANE) f32 tiles -> (nr, LANE)."""
    n = x.shape[0]
    pad = (-n) % (ROWS * LANE)
    if pad:
        x = jnp.pad(x, (0, pad), constant_values=fill)
    return x.reshape(-1, LANE)


def _codes_pallas(values, scale_vec, mode: str, interpret: bool):
    """(codes, dq) over the padded value tiles, one pallas_call."""
    x2d = _tiles(values)
    nb = x2d.shape[0] // ROWS
    spec = pl.BlockSpec((ROWS, LANE), lambda i: (i, 0))
    code_dtype = jnp.uint16 if mode == "bf16" else jnp.int8
    out_shape = (jax.ShapeDtypeStruct(x2d.shape, code_dtype),
                 jax.ShapeDtypeStruct(x2d.shape, jnp.float32))
    if mode == "bf16":
        codes, dq = pl.pallas_call(
            _bf16_kernel, grid=(nb,), in_specs=[spec],
            out_specs=(spec, spec), out_shape=out_shape,
            interpret=interpret)(x2d)
    else:
        kernel = _int8_kernel if mode == "int8" else _tern_kernel
        s2d = _tiles(scale_vec, fill=1.0)   # pad with 1s: no 0-divides
        codes, dq = pl.pallas_call(
            kernel, grid=(nb,), in_specs=[spec, spec],
            out_specs=(spec, spec), out_shape=out_shape,
            interpret=interpret)(x2d, s2d)
    k = values.shape[0]
    return codes.reshape(-1)[:k], dq.reshape(-1)[:k]


def _pack_tern_pallas(codes, interpret: bool):
    """int8 sign codes (k,) -> (ceil(k/4),) packed bytes via the kernel."""
    k = codes.shape[0]
    pad = (-k) % (4 * LANE)
    if pad:
        codes = jnp.pad(codes, (0, pad))
    c2d = codes.reshape(-1, 4 * LANE)
    m = c2d.shape[0]
    packed = pl.pallas_call(
        _tern_pack_kernel, grid=(m,),
        in_specs=[pl.BlockSpec((1, 4 * LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANE), jnp.uint8),
        interpret=interpret)(c2d)
    return packed.reshape(-1)[: (k + 3) // 4]


# ---------------------------------------------------------------------------
# XLA fallback (bit-identical arithmetic; the off-TPU default)
# ---------------------------------------------------------------------------

def _pack_tern_xla(codes):
    k = codes.shape[0]
    u = (codes.astype(jnp.int32) & 3).astype(jnp.uint8)
    pad = (-k) % 4
    if pad:
        u = jnp.pad(u, (0, pad))
    u4 = u.reshape(-1, 4)
    return (u4[:, 0] | (u4[:, 1] << 2) | (u4[:, 2] << 4)
            | (u4[:, 3] << 6)).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "seg", "pallas", "interpret"))
def quantize_pack(values, *, mode: str, seg: tuple,
                  pallas: bool | None = None, interpret: bool = False):
    """One fused program: ``(wire_codes, scales, shipped)`` for a message.

    ``values`` is the concatenated (k,) value vector of an arena message,
    ``seg`` its static per-tensor segmentation (sum == k; each segment
    quantizes with its OWN scale, matching the ARENA frame contract).

    Returns:
      * ``wire_codes`` — the value block exactly as serialized: f32 (none),
        uint16 bf16 bit patterns, int8 codes, or uint8 2-bit-packed tern
        bytes (``ceil(k/4)``, codec ``_pack_tern`` order).
      * ``scales``     — (n_seg,) f32 per-tensor scales (zeros for
        none/bf16, which ship no scales).
      * ``shipped``    — (k,) f32 dequantized values: bit-for-bit what the
        decoder on the far side reconstructs (== ``quantize_segments``).

    ``pallas=None`` routes by backend (Pallas kernels on TPU, plain XLA
    elsewhere — same convention as ``ops.scatter_add``); tests force the
    kernel path with ``pallas=True, interpret=True``.
    """
    if pallas is None:
        pallas = jax.default_backend() == "tpu"
    values = values.astype(jnp.float32)
    k = values.shape[0]
    assert sum(seg) == k, (seg, k)
    if mode == "none":
        return values, jnp.zeros((len(seg),), jnp.float32), values

    # per-segment scale reductions: quantize_parts verbatim (XLA either
    # way — the reduction order must match the legacy encoder exactly)
    parts, off = [], 0
    for s in seg:
        parts.append(quantize_parts(
            jax.lax.slice_in_dim(values, off, off + s), mode))
        off += s
    scales = jnp.stack([p[1] for p in parts])

    if pallas and mode != "none":
        scale_vec = jnp.repeat(scales, jnp.asarray(seg),
                               total_repeat_length=k)
        codes, dq = _codes_pallas(values, scale_vec, mode,
                                  interpret=interpret)
        if mode == "tern":
            codes = _pack_tern_pallas(codes, interpret=interpret)
        return codes, scales, dq

    codes = (parts[0][0] if len(parts) == 1
             else jnp.concatenate([p[0] for p in parts]))
    dq = (parts[0][2] if len(parts) == 1
          else jnp.concatenate([p[2] for p in parts]))
    if mode == "bf16":
        codes = jax.lax.bitcast_convert_type(codes, jnp.uint16)
    elif mode == "tern":
        codes = _pack_tern_xla(codes)
    return codes, scales, dq


@partial(jax.jit, static_argnames=("size",))
def narrow_indices(indices, *, size: int):
    """Size-derived index narrowing, on device (u8 / u16 / u32 — the same
    rule as ``wire.index_dtype``, so the bytes match ``np.astype``)."""
    if size <= 1 << 8:
        return indices.astype(jnp.uint8)
    if size <= 1 << 16:
        return indices.astype(jnp.uint16)
    return indices.astype(jnp.uint32)
