"""Jit'd public wrappers around the Pallas kernels (padding, reshaping,
candidate combine).  These are what the rest of the framework calls.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .block_topk import BLOCK, GROUP, block_topk_2d
from .samomentum_kernel import BLOCK_ROWS, LANE, samomentum_fused_2d


def _pad_to(x, multiple):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, pad


@partial(jax.jit, static_argnames=("momentum", "lr", "interpret"))
def samomentum_fused(u, g, thr, *, momentum: float, lr: float,
                     interpret: bool = True):
    """Fused SAMomentum over an arbitrary-shape tensor.

    Returns (sent_dense, u_new): sent_dense is the thresholded velocity in
    dense layout (zeros where unsent); u_new the rescaled velocity.
    """
    shape = u.shape
    flat_u, _ = _pad_to(u.reshape(-1), BLOCK_ROWS * LANE)
    flat_g, _ = _pad_to(g.reshape(-1).astype(u.dtype), BLOCK_ROWS * LANE)
    u2d = flat_u.reshape(-1, LANE)
    g2d = flat_g.reshape(-1, LANE)
    out, unew = samomentum_fused_2d(u2d, g2d, jnp.asarray(thr),
                                    momentum=momentum, lr=lr,
                                    interpret=interpret)
    n = u.size
    return (out.reshape(-1)[:n].reshape(shape),
            unew.reshape(-1)[:n].reshape(shape))


@partial(jax.jit, static_argnames=("r", "interpret"))
def block_topk_candidates(x, *, r: int, interpret: bool = True):
    """Per-block top-r winners of |x|.  Returns (vals, global_idx), each
    (nb, r); padding elements (|x| = 0 at index >= x.size) may appear only
    when a block is entirely padding."""
    flat, _ = _pad_to(x.reshape(-1), BLOCK * GROUP)
    x2d = flat.reshape(-1, BLOCK)
    vals, idx = block_topk_2d(x2d, r=r, interpret=interpret)
    gidx = idx + (jnp.arange(x2d.shape[0], dtype=jnp.int32) * BLOCK)[:, None]
    return vals, gidx


@partial(jax.jit, static_argnames=("k", "r", "interpret"))
def hierarchical_topk(x, *, k: int, r: int | None = None,
                      interpret: bool = True):
    """Top-k |x| selection via block winners + candidate top-k.

    Exact iff r >= k; production callers pass r << k for the approximate
    (oversampled) mode.  Returns (values, indices) into flattened x.
    """
    if r is None:
        r = k
    r = min(r, BLOCK)
    vals, gidx = block_topk_candidates(x, r=r, interpret=interpret)
    cvals = vals.reshape(-1)
    cidx = gidx.reshape(-1)
    # padding candidates (index >= x.size, |x| = 0) rank strictly below every
    # real candidate, so they can only be selected when k exceeds the number
    # of real candidates
    mag = jnp.where(cidx < x.size, jnp.abs(cvals), -1.0)
    _, sel = jax.lax.top_k(mag, min(k, cvals.shape[0]))
    return cvals[sel], cidx[sel]


def scatter_add(dense, indices, values, *, interpret: bool | None = None):
    """One fused scatter-add on a flat arena: ``dense.at[indices].add(v)``.

    The single entry point behind the arena runtime's three hot scatters
    (server receive, ``v_k`` commit, worker apply).  On TPU it routes to the
    blocked Pallas :func:`scatter_apply` kernel (one HBM pass over the
    parameter vector, bucketed contiguous DMA for the updates); elsewhere it
    stays on the XLA scatter — interpret-mode Pallas would serialize the
    block loop in Python and lose the very dispatch-count war the arena
    wins.  Duplicate indices accumulate in both paths.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return dense.at[indices].add(values.astype(dense.dtype))
    return scatter_apply(dense, indices, values, interpret=False)


def scatter_add_row(dense2d, row, indices, values, *,
                    interpret: bool | None = None):
    """``dense2d.at[row, indices].add(values)`` — one worker row of the
    server's ``v`` buffer.  Off-TPU this is a single 2-D XLA scatter (no
    row gather/set round trip); on TPU the row is sliced, run through the
    blocked Pallas kernel, and written back."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return dense2d.at[row, indices].add(values.astype(dense2d.dtype))
    new_row = scatter_apply(dense2d[row], indices, values, interpret=False)
    return dense2d.at[row].set(new_row)


@partial(jax.jit, static_argnames=("cap", "interpret"))
def scatter_apply(dense, indices, values, *, cap: int | None = None,
                  interpret: bool = True):
    """dense.at[indices].add(values) via the blocked Pallas kernel.

    The wrapper buckets updates by dense block (sort + rank), pads each
    bucket to ``cap`` and runs kernels/scatter_apply.py.  Duplicate indices
    accumulate.  ``cap`` must upper-bound the densest block's update count
    (defaults to k, always safe).
    """
    from .scatter_apply import BLOCK, scatter_apply_blocked
    shape = dense.shape
    flat, pad = _pad_to(dense.reshape(-1), BLOCK)
    nb = flat.shape[0] // BLOCK
    k = values.shape[0]
    cap = min(k, cap) if cap else k
    block_of = indices // BLOCK
    order = jnp.argsort(block_of)
    b_s = block_of[order]
    i_s = indices[order]
    v_s = values[order].astype(jnp.float32)
    rank = jnp.arange(k, dtype=jnp.int32) - jnp.searchsorted(
        b_s, b_s, side="left").astype(jnp.int32)
    ok = rank < cap
    slot = jnp.where(ok, b_s * cap + rank, nb * cap)
    vals2d = jnp.zeros((nb * cap + 1,), jnp.float32).at[slot].add(
        jnp.where(ok, v_s, 0.0))[:-1].reshape(nb, cap)
    offs2d = jnp.full((nb * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(ok, i_s % BLOCK, -1))[:-1].reshape(nb, cap)
    # overflow beyond cap falls back to XLA scatter (exactness guard)
    spill = jnp.zeros_like(flat).at[jnp.where(ok, flat.shape[0], i_s)].add(
        jnp.where(ok, 0.0, v_s).astype(dense.dtype), mode="drop")
    out = scatter_apply_blocked(flat.reshape(nb, BLOCK),
                                vals2d, offs2d, interpret=interpret)
    out = out.reshape(-1) + spill
    n = dense.size
    return out[:n].reshape(shape)
