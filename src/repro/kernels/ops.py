"""Jit'd public wrappers around the Pallas kernels (padding, reshaping,
candidate combine).  These are what the rest of the framework calls.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .block_topk import BLOCK, GROUP, block_topk_2d
from .samomentum_kernel import BLOCK_ROWS, LANE, samomentum_fused_2d


def _pad_to(x, multiple):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, pad


@partial(jax.jit, static_argnames=("momentum", "lr", "interpret"))
def samomentum_fused(u, g, thr, *, momentum: float, lr: float,
                     interpret: bool = True):
    """Fused SAMomentum over an arbitrary-shape tensor.

    Returns (sent_dense, u_new): sent_dense is the thresholded velocity in
    dense layout (zeros where unsent); u_new the rescaled velocity.
    """
    shape = u.shape
    flat_u, _ = _pad_to(u.reshape(-1), BLOCK_ROWS * LANE)
    flat_g, _ = _pad_to(g.reshape(-1).astype(u.dtype), BLOCK_ROWS * LANE)
    u2d = flat_u.reshape(-1, LANE)
    g2d = flat_g.reshape(-1, LANE)
    out, unew = samomentum_fused_2d(u2d, g2d, jnp.asarray(thr),
                                    momentum=momentum, lr=lr,
                                    interpret=interpret)
    n = u.size
    return (out.reshape(-1)[:n].reshape(shape),
            unew.reshape(-1)[:n].reshape(shape))


@partial(jax.jit, static_argnames=("r", "interpret"))
def block_topk_candidates(x, *, r: int, interpret: bool = True):
    """Per-block top-r winners of |x|.  Returns (vals, global_idx), each
    (nb, r); padding elements (|x| = 0 at index >= x.size) may appear only
    when a block is entirely padding."""
    flat, _ = _pad_to(x.reshape(-1), BLOCK * GROUP)
    x2d = flat.reshape(-1, BLOCK)
    vals, idx = block_topk_2d(x2d, r=r, interpret=interpret)
    gidx = idx + (jnp.arange(x2d.shape[0], dtype=jnp.int32) * BLOCK)[:, None]
    return vals, gidx


@partial(jax.jit, static_argnames=("k", "r", "interpret"))
def hierarchical_topk(x, *, k: int, r: int | None = None,
                      interpret: bool = True):
    """Top-k |x| selection via block winners + candidate top-k.

    Exact iff r >= k; production callers pass r << k for the approximate
    (oversampled) mode.  Returns (values, indices) into flattened x.
    """
    if r is None:
        r = k
    r = min(r, BLOCK)
    vals, gidx = block_topk_candidates(x, r=r, interpret=interpret)
    cvals = vals.reshape(-1)
    cidx = gidx.reshape(-1)
    # padding candidates (index >= x.size, |x| = 0) rank strictly below every
    # real candidate, so they can only be selected when k exceeds the number
    # of real candidates
    mag = jnp.where(cidx < x.size, jnp.abs(cvals), -1.0)
    _, sel = jax.lax.top_k(mag, min(k, cvals.shape[0]))
    return cvals[sel], cidx[sel]


def scatter_add(dense, indices, values, *, interpret: bool | None = None):
    """One fused scatter-add on a flat arena: ``dense.at[indices].add(v)``.

    The single entry point behind the arena runtime's three hot scatters
    (server receive, ``v_k`` commit, worker apply).  On TPU it routes to the
    blocked Pallas :func:`scatter_apply` kernel (one HBM pass over the
    parameter vector, bucketed contiguous DMA for the updates); elsewhere it
    stays on the XLA scatter — interpret-mode Pallas would serialize the
    block loop in Python and lose the very dispatch-count war the arena
    wins.  Duplicate indices accumulate in both paths.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return dense.at[indices].add(values.astype(dense.dtype))
    return scatter_apply(dense, indices, values, interpret=False)


def scatter_add_row(dense2d, row, indices, values, *,
                    interpret: bool | None = None):
    """``dense2d.at[row, indices].add(values)`` — one worker row of the
    server's ``v`` buffer.  Off-TPU this is a single 2-D XLA scatter (no
    row gather/set round trip); on TPU the row is sliced, run through the
    blocked Pallas kernel, and written back."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return dense2d.at[row, indices].add(values.astype(dense2d.dtype))
    new_row = scatter_apply(dense2d[row], indices, values, interpret=False)
    return dense2d.at[row].set(new_row)


def scatter_add_rows(dense2d, rows, idx2d, vals2d, *,
                     interpret: bool | None = None):
    """Batched multi-row scatter-add — the batched commit stage's ONE op.

    ``dense2d.at[rows[b], idx2d[b]].add(vals2d[b])`` for every batch lane
    ``b``.  ``rows`` must be pairwise distinct (the batching rule —
    ``async_sim.batch_schedule``); the per-lane scatters then touch
    disjoint rows, so one fused scatter is bit-equal to any serial order
    of :func:`scatter_add_row` calls.  Off-TPU this is a single 2-D XLA
    scatter; on TPU the rows are gathered, run through the blocked
    multi-row Pallas kernel (grid over (lane, block)), and written back.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return dense2d.at[rows[:, None], idx2d].add(
            vals2d.astype(dense2d.dtype))
    sub = scatter_apply_rows(dense2d[rows], idx2d, vals2d, interpret=False)
    return dense2d.at[rows].set(sub)


def _bucket_blocked(n_pad: int, block: int, cap: int, indices, values,
                    out_dtype):
    """Bucket flat scatter updates by dense block (sort + rank).

    Returns ``(vals2d, offs2d, spill)``: the ``(nb, cap)`` kernel inputs
    (block-local offsets, -1 = padding) and a ``(n_pad,)`` XLA-scatter
    remainder for updates past ``cap`` in their block (exactness guard).
    Shared by the flat and multi-row scatter wrappers.
    """
    nb = n_pad // block
    k = values.shape[0]
    block_of = indices // block
    order = jnp.argsort(block_of)
    b_s = block_of[order]
    i_s = indices[order]
    v_s = values[order].astype(jnp.float32)
    rank = jnp.arange(k, dtype=jnp.int32) - jnp.searchsorted(
        b_s, b_s, side="left").astype(jnp.int32)
    ok = rank < cap
    slot = jnp.where(ok, b_s * cap + rank, nb * cap)
    vals2d = jnp.zeros((nb * cap + 1,), jnp.float32).at[slot].add(
        jnp.where(ok, v_s, 0.0))[:-1].reshape(nb, cap)
    offs2d = jnp.full((nb * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(ok, i_s % block, -1))[:-1].reshape(nb, cap)
    spill = jnp.zeros((n_pad,), out_dtype).at[
        jnp.where(ok, n_pad, i_s)].add(
        jnp.where(ok, 0.0, v_s).astype(out_dtype), mode="drop")
    return vals2d, offs2d, spill


@partial(jax.jit, static_argnames=("cap", "interpret"))
def scatter_apply(dense, indices, values, *, cap: int | None = None,
                  interpret: bool = True):
    """dense.at[indices].add(values) via the blocked Pallas kernel.

    The wrapper buckets updates by dense block (sort + rank), pads each
    bucket to ``cap`` and runs kernels/scatter_apply.py.  Duplicate indices
    accumulate.  ``cap`` must upper-bound the densest block's update count
    (defaults to k, always safe).
    """
    from .scatter_apply import BLOCK, scatter_apply_blocked
    shape = dense.shape
    flat, _ = _pad_to(dense.reshape(-1), BLOCK)
    nb = flat.shape[0] // BLOCK
    k = values.shape[0]
    cap = min(k, cap) if cap else k
    vals2d, offs2d, spill = _bucket_blocked(
        flat.shape[0], BLOCK, cap, indices, values, dense.dtype)
    out = scatter_apply_blocked(flat.reshape(nb, BLOCK),
                                vals2d, offs2d, interpret=interpret)
    out = out.reshape(-1) + spill
    n = dense.size
    return out[:n].reshape(shape)


@partial(jax.jit, static_argnames=("cap", "interpret"))
def scatter_apply_rows(dense2d, idx2d, vals2d, *, cap: int | None = None,
                       interpret: bool = True):
    """Row-wise ``dense2d[b].at[idx2d[b]].add(vals2d[b])`` via ONE blocked
    Pallas dispatch over a (row, block) grid.

    The bucketing is the same sort + rank as :func:`scatter_apply`, vmapped
    over the batch lanes; the kernel then streams every lane's blocks
    through VMEM in a single pallas_call instead of one dispatch per lane.
    """
    from .scatter_apply import BLOCK, scatter_apply_blocked_rows
    n_rows, n = dense2d.shape
    pad = (-n) % BLOCK
    flat = jnp.pad(dense2d, ((0, 0), (0, pad))) if pad else dense2d
    nb = flat.shape[1] // BLOCK
    k = vals2d.shape[1]
    cap = min(k, cap) if cap else k
    vals3d, offs3d, spill = jax.vmap(
        lambda i, v: _bucket_blocked(flat.shape[1], BLOCK, cap, i, v,
                                     dense2d.dtype))(idx2d, vals2d)
    out = scatter_apply_blocked_rows(flat.reshape(n_rows, nb, BLOCK),
                                     vals3d, offs3d, interpret=interpret)
    out = out.reshape(n_rows, -1) + spill
    return out[:, :n]


# ---------------------------------------------------------------------------
# shard routing — the in-graph half of the alltoallv exchange
# ---------------------------------------------------------------------------

def route_by_shard(indices, values, *, bounds, n_shards: int, cap: int,
                   interpret: bool | None = None):
    """Bucket one global-index sparse message into per-shard slots.

    ``indices``: ``(k,)`` int32 global arena indices (``-1`` marks padding);
    ``values``: ``(k,)``.  ``bounds`` is the ``(S+1,)`` ascending
    ``ShardSpec.bounds`` array; ownership is the in-graph twin of the
    host-side ``ShardSpec.owner_of`` (``searchsorted(bounds, i, "right")-1``,
    so duplicate bounds from empty shards resolve to the non-empty owner).

    Returns ``(local_idx, vals, overflow)``: ``(S, cap)`` shard-LOCAL
    indices (``-1`` = empty slot) and values, plus a scalar int32 count of
    real entries dropped because their shard already held ``cap`` — callers
    that need exactness must size ``cap >= k`` (or prove a tighter bound,
    see ``distributed.shard_exchange_batch``).

    The slot math is the same stable sort + rank idiom as
    :func:`_bucket_blocked`; the value placement funnels through
    :func:`scatter_add`, so on TPU it is the blocked Pallas scatter and
    elsewhere a single XLA scatter.
    """
    ri, rv, ovf = route_by_shard_batch(indices[None], values[None],
                                       bounds=bounds, n_shards=n_shards,
                                       cap=cap, interpret=interpret)
    return ri[0], rv[0], ovf


def route_by_shard_batch(indices, values, *, bounds, n_shards: int, cap: int,
                         interpret: bool | None = None):
    """Batched :func:`route_by_shard` over ``(N, k)`` chunks with ONE
    scatter dispatch.

    Rather than vmapping the scatter (which would trace N pallas_calls on
    TPU), every chunk's slots are offset by ``chunk * (S*cap + 1)`` into a
    single flat buffer — one kernel launch routes the whole batch.
    Returns ``(local_idx, vals, overflow)`` shaped ``(N, S, cap)`` /
    ``(N, S, cap)`` / scalar.
    """
    S = int(n_shards)
    cap = int(cap)
    n, k = indices.shape
    bounds = jnp.asarray(bounds, jnp.int32)
    # padding entries (-1) route to the virtual shard S and are dropped
    owner = jnp.where(
        indices < 0, jnp.int32(S),
        jnp.searchsorted(bounds, indices, side="right").astype(jnp.int32) - 1)
    order = jnp.argsort(owner, axis=1, stable=True)
    o_s = jnp.take_along_axis(owner, order, axis=1)
    i_s = jnp.take_along_axis(indices, order, axis=1)
    v_s = jnp.take_along_axis(values, order, axis=1).astype(jnp.float32)
    first = jax.vmap(
        lambda o: jnp.searchsorted(o, o, side="left"))(o_s).astype(jnp.int32)
    rank = jnp.arange(k, dtype=jnp.int32)[None, :] - first
    real = o_s < S
    ok = (rank < cap) & real
    row_len = S * cap + 1  # one dump slot per chunk
    slot = jnp.where(ok, o_s * cap + rank, S * cap)
    local = i_s - bounds[jnp.clip(o_s, 0, S - 1)]
    flat = (slot + jnp.arange(n, dtype=jnp.int32)[:, None] * row_len).reshape(-1)
    rv = scatter_add(jnp.zeros((n * row_len,), jnp.float32), flat,
                     jnp.where(ok, v_s, 0.0).reshape(-1),
                     interpret=interpret)
    rv = rv.reshape(n, row_len)[:, :-1].reshape(n, S, cap)
    ri = jnp.full((n * row_len,), -1, jnp.int32).at[flat].set(
        jnp.where(ok, local, -1).reshape(-1))
    ri = ri.reshape(n, row_len)[:, :-1].reshape(n, S, cap)
    overflow = jnp.sum(real & (rank >= cap)).astype(jnp.int32)
    return ri, rv, overflow
