"""Hierarchical block top-k candidate selection — Pallas TPU kernel.

Exact ``lax.top_k`` over a 100M-element gradient is a full sort on TPU.  The
paper's threshold ("R% of |v|") doesn't need a sort: DGS only needs the top
~k set.  This kernel adapts the hierarchical-selection idea to the TPU
memory hierarchy: each VMEM-resident block of 1024 elements emits its local
top-r by magnitude via r unrolled (max, mask) reduction sweeps on the VPU —
no sort, one HBM pass.  A cheap host-side ``lax.top_k`` over the nb*r
candidates then yields the final selection:

* exact whenever r >= k (every global winner is a block winner), used by
  tests;
* with r = oversample * k/nb it is the production approximation (same
  spirit as DGC's sampled threshold; gradient sparsification tolerates it —
  unsent mass stays in the SAMomentum velocity).

Layout: (nb, block) view, block = 8 sublanes x 128 lanes; grid walks
row-groups of G blocks.

Semantics contract: kernels/ref.py::block_topk_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024     # elements per block (8 x 128 tile)
GROUP = 8        # blocks per kernel invocation


def _kernel(x_ref, vals_ref, idx_ref, *, r: int):
    x = x_ref[...].astype(jnp.float32)          # (G, BLOCK)
    mag = jnp.abs(x)
    cols = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)
    rows = jnp.arange(mag.shape[0])
    for j in range(r):                          # unrolled selection sweeps
        m = jnp.argmax(mag, axis=1)             # (G,)
        vals_ref[:, j] = x[rows, m]
        idx_ref[:, j] = m.astype(jnp.int32)
        mag = jnp.where(cols == m[:, None], -jnp.inf, mag)


def block_topk_2d(x2d, *, r: int, interpret: bool = True):
    """x2d: (nb, BLOCK), nb % GROUP == 0 -> (vals (nb, r), idx (nb, r) local
    per-block indices)."""
    nb = x2d.shape[0]
    assert x2d.shape[1] == BLOCK and nb % GROUP == 0, x2d.shape
    grid = (nb // GROUP,)
    in_spec = pl.BlockSpec((GROUP, BLOCK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((GROUP, r), lambda i: (i, 0))
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, r=r),
        grid=grid,
        in_specs=[in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, r), x2d.dtype),
            jax.ShapeDtypeStruct((nb, r), jnp.int32),
        ],
        interpret=interpret,
    )(x2d)
    return vals, idx
