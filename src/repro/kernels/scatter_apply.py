"""Blocked sparse scatter-apply — Pallas TPU kernel.

Applying a decoded sparse update (``dense.at[idx].add(vals)``) is a random
scatter: on TPU the efficient form is to pre-bucket the updates by parameter
block (a cheap sort on the host side of the op), then stream each dense
block through VMEM exactly once and apply its updates with on-chip dynamic
stores.  One HBM round-trip for the parameter vector, no atomics (the TPU
grid is sequential), contiguous DMA for both the parameters and the
bucketed updates.

Layout: params viewed as (n_blocks, BLOCK); updates pre-bucketed to
(n_blocks, CAP) value/offset pairs padded with offset == -1.

This is the TPU fast path behind ``ops.scatter_add`` — the ONE fused
scatter the flat-arena runtime (core/paramspace.py) runs per event for
server receive, ``v_k`` commit, and worker apply.  A whole model's sparse
update is a single global-index COO over the packed arena, so the kernel
sees one big bucketed scatter instead of one tiny scatter per tensor.

Semantics contract: kernels/ref.py::scatter_accumulate_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048      # dense elements per block (16 x 128 tile)


def _kernel(vals_ref, offs_ref, dense_ref, out_ref, *, cap: int):
    block = dense_ref[...]          # (1, BLOCK)
    vals = vals_ref[...]            # (1, CAP)
    offs = offs_ref[...]            # (1, CAP)
    lanes = jax.lax.broadcasted_iota(jnp.int32, block.shape, 1)

    def body(j, acc):
        off = offs[0, j]
        val = vals[0, j]
        hit = (lanes == off) & (off >= 0)
        return acc + jnp.where(hit, val, 0.0).astype(acc.dtype)

    out_ref[...] = jax.lax.fori_loop(0, cap, body, block)


def scatter_apply_blocked(dense2d, vals2d, offs2d, *, interpret: bool = True):
    """dense2d: (nb, BLOCK); vals2d/offs2d: (nb, CAP) bucketed updates
    (offset local to the block, -1 = padding).  Returns updated dense2d."""
    nb, cap = vals2d.shape
    assert dense2d.shape == (nb, BLOCK), (dense2d.shape, nb)
    spec_d = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    spec_u = pl.BlockSpec((1, cap), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        grid=(nb,),
        in_specs=[spec_u, spec_u, spec_d],
        out_specs=spec_d,
        out_shape=jax.ShapeDtypeStruct(dense2d.shape, dense2d.dtype),
        interpret=interpret,
    )(vals2d, offs2d, dense2d)


def _rows_kernel(vals_ref, offs_ref, dense_ref, out_ref, *, cap: int):
    block = dense_ref[...]          # (1, 1, BLOCK)
    vals = vals_ref[...]            # (1, 1, CAP)
    offs = offs_ref[...]
    lanes = jax.lax.broadcasted_iota(jnp.int32, block.shape, 2)

    def body(j, acc):
        off = offs[0, 0, j]
        val = vals[0, 0, j]
        hit = (lanes == off) & (off >= 0)
        return acc + jnp.where(hit, val, 0.0).astype(acc.dtype)

    out_ref[...] = jax.lax.fori_loop(0, cap, body, block)


def scatter_apply_blocked_rows(dense3d, vals3d, offs3d, *,
                               interpret: bool = True):
    """Multi-row variant for the batched event loop's commit stage.

    dense3d: (n_rows, nb, BLOCK) — one blocked parameter row per batch
    lane; vals3d/offs3d: (n_rows, nb, CAP) per-lane bucketed updates.  The
    grid is (n_rows, nb): every lane's every block streams through VMEM
    exactly once, so a whole commit batch costs the same HBM traffic as
    one row costs per lane — no per-event dispatch, no atomics (rows are
    disjoint by construction, the grid is sequential anyway).
    """
    n_rows, nb, cap = vals3d.shape
    assert dense3d.shape == (n_rows, nb, BLOCK), (dense3d.shape, n_rows, nb)
    spec_d = pl.BlockSpec((1, 1, BLOCK), lambda b, i: (b, i, 0))
    spec_u = pl.BlockSpec((1, 1, cap), lambda b, i: (b, i, 0))
    return pl.pallas_call(
        functools.partial(_rows_kernel, cap=cap),
        grid=(n_rows, nb),
        in_specs=[spec_u, spec_u, spec_d],
        out_specs=spec_d,
        out_shape=jax.ShapeDtypeStruct(dense3d.shape, dense3d.dtype),
        interpret=interpret,
    )(vals3d, offs3d, dense3d)
