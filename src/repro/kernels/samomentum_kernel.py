"""Fused SAMomentum update — Pallas TPU kernel.

The SAMomentum inner loop (velocity accumulate -> threshold compare ->
rescale unsent) is four elementwise HBM passes when written naively
(u read, g read, u write, out write, plus the compare).  On TPU this is
purely memory-bound, so fusing it into one pass over VMEM tiles halves the
HBM traffic of the optimizer stage (see EXPERIMENTS.md §Perf).

Layout: the flattened tensor is viewed as (rows, 128) — lane dim 128, tile
sublane 8 — and the grid walks row-blocks.  The magnitude threshold ``thr``
(computed by block_topk.py or a sampled estimator) arrives as a (1, 1)
scalar prefetch block.

Semantics contract: kernels/ref.py::samomentum_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256     # (256, 128) f32 tile = 128 KiB VMEM per operand


def _kernel(thr_ref, u_ref, g_ref, out_ref, unew_ref, *, momentum, lr):
    u = u_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    thr = thr_ref[0, 0]
    uacc = momentum * u + lr * g
    sent = jnp.abs(uacc) >= thr
    out_ref[...] = jnp.where(sent, uacc, 0.0).astype(out_ref.dtype)
    unew_ref[...] = jnp.where(sent, uacc, uacc / momentum).astype(
        unew_ref.dtype)


def samomentum_fused_2d(u2d, g2d, thr, *, momentum: float, lr: float,
                        interpret: bool = True):
    """u2d/g2d: (rows, 128) with rows % BLOCK_ROWS == 0. thr: (1,1) f32."""
    rows = u2d.shape[0]
    assert u2d.shape[1] == LANE and rows % BLOCK_ROWS == 0, u2d.shape
    grid = (rows // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(u2d.shape, u2d.dtype),
        jax.ShapeDtypeStruct(u2d.shape, u2d.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, momentum=momentum, lr=lr),
        grid=grid,
        in_specs=[scalar_spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(thr.reshape(1, 1).astype(jnp.float32), u2d, g2d)
