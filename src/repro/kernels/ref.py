"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: tests sweep shapes/dtypes and assert the
kernels (run with interpret=True on CPU) match these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def samomentum_ref(u, g, thr, *, momentum: float, lr: float):
    """Fused SAMomentum step against a precomputed magnitude threshold.

    u_acc   = momentum * u + lr * g
    sent    = |u_acc| >= thr            (ties INCLUDED, matching kernel)
    out     = u_acc * sent              (the shipped values, dense layout)
    u_new   = where(sent, u_acc, u_acc / momentum)

    Returns (out, u_new, sent).
    """
    uacc = momentum * u.astype(jnp.float32) + lr * g.astype(jnp.float32)
    sent = jnp.abs(uacc) >= thr
    out = jnp.where(sent, uacc, 0.0)
    u_new = jnp.where(sent, uacc, uacc / momentum)
    return out.astype(u.dtype), u_new.astype(u.dtype), sent


def block_topk_ref(x, *, block: int, r: int):
    """Hierarchical top-k candidate selection, reference.

    The input is viewed as blocks of ``block`` elements (padded with -inf
    magnitude); within each block the r largest |x| are selected.  Returns
    (values (nb, r), indices (nb, r) GLOBAL into the flattened input).
    The union of block winners is a superset of the global top-(r) per
    block; a host-side final top-k over nb*r candidates yields the exact
    global top-k whenever k <= nb * r and every block contributes its own
    top-r (guaranteed: the global top-k contains at most r elements of a
    block only if k <= r... callers choose r >= ceil(k / nb) * safety).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    # zero padding (matching the kernel wrapper): padded positions can win a
    # candidate slot only against other zeros — harmless for selection
    mag = jnp.pad(jnp.abs(flat), (0, pad))
    vals = jnp.pad(flat, (0, pad))
    mag = mag.reshape(nb, block)
    vals = vals.reshape(nb, block)
    _, idx = jax.lax.top_k(mag, r)                       # (nb, r)
    winners = jnp.take_along_axis(vals, idx, axis=1)
    gidx = idx + jnp.arange(nb)[:, None] * block
    return winners, gidx.astype(jnp.int32)


def scatter_accumulate_ref(dense, indices, values):
    """dense.at[indices].add(values) with duplicate indices accumulated."""
    return dense.at[indices].add(values.astype(dense.dtype))
