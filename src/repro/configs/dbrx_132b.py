"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,               # per-expert FFN hidden
    vocab_size=100352,
    head_dim=128,
    attention="full",
    rope="standard",
    rope_theta=500_000.0,
    norm="layernorm",
    activation="swiglu",
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752,
                  capacity_factor=1.25, impl="capacity"),
    window=8192,
    long_context="sliding_window",
    source="hf:databricks/dbrx-base",
)
