"""musicgen-large [audio] — decoder-only over EnCodec tokens; the EnCodec
feature frontend is a stub supplying frame embeddings [arXiv:2306.05284]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,            # MHA
    d_ff=8192,
    vocab_size=2048,          # EnCodec codebook size
    head_dim=64,
    attention="full",
    rope="none",              # sinusoidal absolute positions
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    frontend_tokens=512,      # stub: conditioning frame embeddings
    window=8192,
    long_context="sliding_window",
    source="arXiv:2306.05284 (MusicGen-large)",
)
