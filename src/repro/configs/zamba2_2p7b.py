"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,            # shared attention block is MHA
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    attention="full",
    attn_every=6,             # shared attention block every 6 mamba layers
    shared_attention=True,
    rope="standard",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="gelu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    long_context="native",    # SSM state is O(1); shared-attn cache linear
    source="arXiv:2411.15242 (Zamba2)",
)
