"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2 [arXiv:2406.12793]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    attention="full",
    rope="standard",
    rotary_pct=0.5,          # GLM applies rotary to half the head dims
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    qkv_bias=True,
    window=8192,             # used only by the long_500k substitution
    long_context="sliding_window",
    source="arXiv:2406.12793 (ChatGLM family; GLM 2D/partial rotary, GQA kv=2)",
)
