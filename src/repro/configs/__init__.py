"""Architecture registry: the 10 assigned architectures (+ paper-scale
models for the convergence benchmarks) and the 4 assigned input shapes."""
from __future__ import annotations

from repro.models.config import ModelConfig

from . import shapes as shapes_mod
from .chatglm3_6b import CONFIG as chatglm3_6b
from .command_r_35b import CONFIG as command_r_35b
from .dbrx_132b import CONFIG as dbrx_132b
from .gemma3_12b import CONFIG as gemma3_12b
from .mamba2_780m import CONFIG as mamba2_780m
from .minicpm3_4b import CONFIG as minicpm3_4b
from .musicgen_large import CONFIG as musicgen_large
from .qwen2_vl_7b import CONFIG as qwen2_vl_7b
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from .shapes import SHAPES, InputShape, concrete_inputs, input_specs
from .zamba2_2p7b import CONFIG as zamba2_2p7b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        chatglm3_6b, gemma3_12b, zamba2_2p7b, qwen2_vl_7b, dbrx_132b,
        musicgen_large, mamba2_780m, command_r_35b, minicpm3_4b,
        qwen3_moe_235b_a22b,
    ]
}


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise ValueError(f"unknown shape {name!r}; have {sorted(SHAPES)}")


__all__ = [
    "ARCHS", "SHAPES", "InputShape", "ModelConfig", "concrete_inputs",
    "get_arch", "get_shape", "input_specs",
]
