"""command-r-35b [dense] — GQA kv=8, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    attention="full",
    rope="standard",
    rope_theta=8_000_000.0,
    norm="layernorm",
    activation="swiglu",
    qkv_bias=False,
    tie_embeddings=True,
    window=8192,
    long_context="sliding_window",
    source="hf:CohereForAI/c4ai-command-r-v01",
)
