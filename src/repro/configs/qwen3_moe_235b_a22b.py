"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, fine-grained
[hf:Qwen/Qwen3-30B-A3B scaled per Qwen3-235B-A22B card]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                # per-expert (fine-grained experts)
    vocab_size=151936,
    head_dim=128,
    attention="full",
    rope="standard",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536,
                  capacity_factor=1.25, impl="capacity"),
    window=8192,
    long_context="sliding_window",
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B geometry)",
)
