"""mamba2-780m [ssm] — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,                # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                   # no FFN: mamba blocks only
    vocab_size=50280,
    attention="none",
    rope="none",
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    long_context="native",    # O(1) recurrent state
    source="arXiv:2405.21060 (Mamba2-780m)",
)
