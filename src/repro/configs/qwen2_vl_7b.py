"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution; vision tower is a stub
that supplies patch embeddings (assignment carve-out) [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    attention="full",
    rope="mrope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    activation="swiglu",
    qkv_bias=True,
    frontend="vision",
    frontend_tokens=1024,     # stub: 32x32 patch grid per sequence
    window=8192,
    long_context="sliding_window",
    source="arXiv:2409.12191 (Qwen2-VL-7B)",
)
