"""minicpm3-4b [dense] — MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B]."""
from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,            # MLA: every head reads the shared latent
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64, absorb=False),
    rope="standard",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    window=8192,
    long_context="sliding_window",
    source="hf:openbmb/MiniCPM3-4B",
)
