"""gemma3-12b [dense] — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    attention="local_global",
    local_global_ratio=5,     # 5 sliding-window layers per global layer
    window=1024,
    rope="standard",
    rope_theta=1_000_000.0,   # global layers
    rope_theta_local=10_000.0,
    norm="rmsnorm",
    activation="geglu",
    tie_embeddings=True,
    long_context="native",    # 40/48 layers are windowed already
    source="hf:google/gemma-3-1b-pt scaled per gemma-3-12b card",
)
