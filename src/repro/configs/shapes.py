"""The four assigned input shapes and per-(arch, shape) input specs.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import config as mcfg
from repro.models import init_caches


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode
    long: bool = False  # long-context decode (sliding-window substitution)


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode", long=True),
}


def input_specs(cfg: mcfg.ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for one (architecture, input shape)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.frontend_tokens:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), cfg.cdtype)
        return specs
    # decode: one new token against a seq_len cache
    caches = jax.eval_shape(
        lambda: init_caches(cfg, B, S, long_mode=shape.long))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }


def concrete_inputs(cfg: mcfg.ModelConfig, shape: InputShape, *, seed=0):
    """Small-scale concrete inputs (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
        if cfg.frontend_tokens:
            out["frontend_embeds"] = jax.random.normal(
                key, (B, cfg.frontend_tokens, cfg.d_model), cfg.cdtype)
        return out
    return {
        "token": jax.random.randint(key, (B, 1), 0, cfg.vocab_size),
        "pos": jnp.int32(S // 2),
        "caches": init_caches(cfg, B, S, long_mode=shape.long),
    }
