"""Modality frontend STUBS (the one sanctioned carve-out, see task spec).

For the [vlm] and [audio] architectures we implement the decoder transformer
only; ``input_specs()`` supplies precomputed frame/patch embeddings of the
right shape (as a real ViT/SigLIP tower or EnCodec feature extractor would).
The stub merges those embeddings into the token stream and (for Qwen2-VL)
builds the 3-stream M-RoPE position ids for a square patch grid.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig


def merge_frontend(cfg: ModelConfig, token_embeds, frontend_embeds):
    """Replace the first ``frontend_tokens`` positions with stub embeddings.

    token_embeds: (B, S, d); frontend_embeds: (B, n_front, d).
    """
    n = cfg.frontend_tokens
    if n == 0 or frontend_embeds is None:
        return token_embeds
    return jnp.concatenate(
        [frontend_embeds.astype(token_embeds.dtype), token_embeds[:, n:]],
        axis=1,
    )


def mrope_positions(cfg: ModelConfig, batch: int, seq_len: int):
    """(3, B, S) (t, h, w) position ids: a square patch grid for the stub
    image followed by text positions (Qwen2-VL scheme: all three streams
    advance together on text, h/w scan the grid on patches)."""
    n = cfg.frontend_tokens
    g = max(1, int(math.sqrt(max(n, 1))))
    off = g if n > 0 else 0
    idx = jnp.arange(seq_len)
    in_img = idx < n
    row = jnp.where(in_img, idx // g, 0)
    col = jnp.where(in_img, idx % g, 0)
    # text positions continue after the image's spatial extent
    text_pos = off + (idx - n)
    t = jnp.where(in_img, 0, text_pos)
    h = jnp.where(in_img, row, text_pos)
    w = jnp.where(in_img, col, text_pos)
    pos = jnp.stack([t, h, w], axis=0)                  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq_len)).astype(
        jnp.int32)


def mrope_text_position(cfg: ModelConfig, pos):
    """Scalar decode-time (t==h==w) position for a text token at ``pos``
    (generation is always past the frontend region)."""
    n = cfg.frontend_tokens
    off = (max(1, int(math.sqrt(n))) if n > 0 else 0)
    return off + pos - n
