"""Model assembly: decoder-only LM over heterogeneous block stacks.

The layer list of every assigned architecture is expressed as a repeating
``unit pattern`` (ModelConfig.unit_pattern): e.g. gemma3 is 8 units of
[5x local attn, 1x global attn]; zamba2 is 9 units of [5x mamba, 1x mamba +
shared-attention]; uniform stacks are L units of [block].  Parameters are
stacked over units and the forward pass is one ``lax.scan`` — keeping the
compiled HLO size O(pattern), not O(L), which is what makes compiling 94-layer
configs on 512 host devices tractable (DESIGN.md §6).

Three entry points per architecture x input shape:
  train_forward / loss_fn  — training shapes
  prefill                  — forward + KV/SSM cache construction
  decode_step              — one token against the cache (serve_step)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import multimodal
from . import ssm as ssm_lib
from .config import ModelConfig
from .layers import embed, embedding_init, linear, linear_init, norm, norm_init, mlp, mlp_init, unembed

# When True, unit scans are fully unrolled.  Used by the dry-run's
# cost-extrapolation lowering: XLA's cost_analysis counts while-loop bodies
# ONCE regardless of trip count, so roofline terms are measured on small
# UNROLLED variants and extrapolated linearly in the unit count
# (launch/dryrun.py).
_SCAN_UNROLL = False


class scan_unrolled:
    """Context manager: fully unroll the per-unit scans while active."""

    def __enter__(self):
        global _SCAN_UNROLL
        self._prev = _SCAN_UNROLL
        _SCAN_UNROLL = True

    def __exit__(self, *exc):
        global _SCAN_UNROLL
        _SCAN_UNROLL = self._prev


def _scan(body, init, xs):
    n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=n if _SCAN_UNROLL else 1)


# ------------------------------------------------------------------- init --

def _block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind.startswith("attn"):
        use_mla = cfg.attention == "mla"
        p = {
            "norm1": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
            "attn": (attn.mla_init if use_mla else attn.gqa_init)(ks[0], cfg),
            "norm2": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
        }
        if cfg.moe is not None:
            p["moe"] = moe_lib.moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                activation=cfg.activation, dtype=cfg.pdtype)
        return p
    if kind in ("mamba", "mamba_attn"):
        return {
            "norm1": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
            "mamba": ssm_lib.mamba_init(ks[0], cfg),
        }
    raise ValueError(kind)


def _shared_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
        "attn": attn.gqa_init(ks[0], cfg),
        "norm2": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
        "mlp": mlp_init(ks[1], cfg.d_model, max(cfg.d_ff, 4 * cfg.d_model),
                        activation=cfg.activation, dtype=cfg.pdtype),
    }


def init_params(key, cfg: ModelConfig):
    pattern, n_units = cfg.unit_pattern()
    k_embed, k_units, k_shared, k_head = jax.random.split(key, 4)
    params: dict = {
        "embed": embedding_init(k_embed, cfg.vocab_size, cfg.d_model,
                                dtype=cfg.pdtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype=cfg.pdtype),
    }
    unit_keys = jax.random.split(k_units, n_units)

    def one_unit(uk):
        bks = jax.random.split(uk, len(pattern))
        return {f"b{i}": _block_init(bks[i], cfg, kind)
                for i, kind in enumerate(pattern)}

    units = [one_unit(uk) for uk in unit_keys]
    params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if cfg.shared_attention and any(k == "mamba_attn" for k in pattern):
        params["shared"] = _shared_block_init(k_shared, cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(k_head, cfg.d_model, cfg.vocab_size,
                                        dtype=cfg.pdtype)
    return params


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------- forward --

def _apply_block(bp, h, positions, cfg: ModelConfig, kind: str, shared, *,
                 want_cache: bool = False):
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    cache = None
    if kind.startswith("attn"):
        fwd = attn.mla_forward if cfg.attention == "mla" else attn.gqa_forward
        out = fwd(bp["attn"], norm(cfg.norm, bp["norm1"], h), positions,
                  cfg, layer_kind=kind, return_kv=want_cache)
        if want_cache:
            out, cache = out
        h = h + out
        hn = norm(cfg.norm, bp["norm2"], h)
        if cfg.moe is not None:
            out, aux = moe_lib.moe_forward(bp["moe"], hn, cfg)
            h = h + out
        else:
            h = h + mlp(bp["mlp"], hn, activation=cfg.activation)
        return h, aux, cache
    # mamba (+ optional shared attention afterwards)
    out = ssm_lib.mamba_forward(bp["mamba"], norm(cfg.norm, bp["norm1"], h),
                                cfg, return_state=want_cache)
    if want_cache:
        out, ssm_cache = out
        cache = {"ssm": ssm_cache}
    h = h + out
    if kind == "mamba_attn" and shared is not None:
        pos2 = positions if positions.ndim == 2 else positions[0]
        out = attn.gqa_forward(shared["attn"],
                               norm(cfg.norm, shared["norm1"], h),
                               pos2, cfg, layer_kind="attn",
                               return_kv=want_cache)
        if want_cache:
            out, cache["shared"] = out
        h = h + out
        h = h + mlp(shared["mlp"], norm(cfg.norm, shared["norm2"], h),
                    activation=cfg.activation)
    return h, aux, cache


def _positions_for(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.rope == "mrope":
        return multimodal.mrope_positions(cfg, batch, seq_len)
    return jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32)[None],
                            (batch, seq_len))


def _sinusoidal(d_model: int, positions):
    """Absolute sinusoidal embeddings (musicgen-style decoders, rope='none').

    positions: (B, S) -> (B, S, d_model)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _maybe_add_abs_pos(cfg: ModelConfig, h, positions):
    if cfg.rope == "none" and cfg.arch_type not in ("ssm", "hybrid"):
        p = positions if positions.ndim == 2 else positions[0]
        h = h + _sinusoidal(cfg.d_model, p).astype(h.dtype)
    return h


def forward(params, tokens, cfg: ModelConfig, *, frontend_embeds=None,
            want_cache: bool = False, remat: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V) float32, aux dict
    (and stacked per-unit caches when ``want_cache``, for prefill).

    ``remat=True`` checkpoints each unit (activation recomputation in the
    backward pass) — required at production sequence lengths."""
    pattern, n_units = cfg.unit_pattern()
    B, S = tokens.shape
    h = embed(params["embed"], tokens).astype(cfg.cdtype)
    h = multimodal.merge_frontend(cfg, h, frontend_embeds)
    positions = _positions_for(cfg, B, S)
    h = _maybe_add_abs_pos(cfg, h, positions)
    shared = params.get("shared")

    def unit_fn(carry, unit_params):
        h, lb, rz = carry
        caches = {}
        for i, kind in enumerate(pattern):
            h, aux, cache = _apply_block(unit_params[f"b{i}"], h, positions,
                                         cfg, kind, shared,
                                         want_cache=want_cache)
            if cfg.activation_sharding:
                from jax.sharding import PartitionSpec as _P
                h = jax.lax.with_sharding_constraint(
                    h, _P(None, None, "model"))
            lb = lb + aux["load_balance"]
            rz = rz + aux["router_z"]
            if want_cache:
                caches[f"b{i}"] = cache
        return (h, lb, rz), caches if want_cache else None

    body = jax.checkpoint(unit_fn) if remat else unit_fn
    (h, lb, rz), caches = _scan(
        body,
        (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        params["units"],
    )
    h = norm(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = linear(params["lm_head"], h).astype(jnp.float32)
    # NOTE (§Perf, refuted hypothesis): constraining the tied-head logits to
    # vocab-sharded (reduce-scatter instead of the 12.5 GiB f32 all-reduce)
    # was measured and made the collective term WORSE (+3%): the backward of
    # the constraint re-gathers the same bytes. Kept unconstrained.
    aux = {"load_balance": lb / cfg.n_layers, "router_z": rz / cfg.n_layers}
    if want_cache:
        return logits, aux, caches
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = False):
    """batch: {"tokens": (B,S), optional "frontend_embeds"}.

    Next-token cross entropy (+ MoE aux losses). Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens, cfg,
                          frontend_embeds=batch.get("frontend_embeds"),
                          remat=remat)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    # one-hot formulation (not take_along_axis): partitions cleanly when the
    # vocab dim is sharded over "model" — the gather form trips XLA's SPMD
    # gather partitioner at scale
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt_logit = jnp.sum(
        lg * jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype), axis=-1)
    nll = lse - tgt_logit
    loss = jnp.mean(nll)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * (
            aux["load_balance"] + aux["router_z"])
    return loss, {"nll": jnp.mean(nll), **aux}


# ------------------------------------------------------------ serve paths --

def init_caches(cfg: ModelConfig, batch: int, seq_len: int, *,
                long_mode: bool = False):
    """Stacked (over units) per-block caches."""
    pattern, n_units = cfg.unit_pattern()

    def one_unit():
        caches = {}
        for i, kind in enumerate(pattern):
            if kind.startswith("attn"):
                if cfg.attention == "mla":
                    caches[f"b{i}"] = attn.mla_init_cache(cfg, batch, seq_len)
                else:
                    caches[f"b{i}"] = attn.gqa_init_cache(
                        cfg, batch, seq_len, layer_kind=kind,
                        long_mode=long_mode)
            else:
                c = {"ssm": ssm_lib.mamba_init_cache(cfg, batch, seq_len)}
                if kind == "mamba_attn" and cfg.shared_attention:
                    c["shared"] = attn.gqa_init_cache(
                        cfg, batch, seq_len, layer_kind="attn",
                        long_mode=long_mode)
                caches[f"b{i}"] = c
        return caches

    unit = one_unit()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape),
        unit,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )


def decode_step(params, caches, token, pos, cfg: ModelConfig, *,
                long_mode: bool = False):
    """serve_step: one new token per sequence against the cache.

    token: (B, 1) int32; pos: scalar int32 current position.
    Returns (logits (B, 1, V), new caches).
    """
    pattern, n_units = cfg.unit_pattern()
    B = token.shape[0]
    h = embed(params["embed"], token).astype(cfg.cdtype)
    h = _maybe_add_abs_pos(cfg, h, jnp.full((B, 1), pos, jnp.int32))
    shared = params.get("shared")

    def unit_fn(h, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, kind in enumerate(pattern):
            bp, bc = unit_params[f"b{i}"], unit_cache[f"b{i}"]
            if kind.startswith("attn"):
                dec = (attn.mla_decode if cfg.attention == "mla"
                       else attn.gqa_decode)
                out, nc = dec(bp["attn"], bc,
                              norm(cfg.norm, bp["norm1"], h), pos, cfg,
                              layer_kind=kind, long_mode=long_mode)
                h = h + out
                hn = norm(cfg.norm, bp["norm2"], h)
                if cfg.moe is not None:
                    out, _ = moe_lib.moe_forward(bp["moe"], hn, cfg)
                    h = h + out
                else:
                    h = h + mlp(bp["mlp"], hn, activation=cfg.activation)
                new_cache[f"b{i}"] = nc
            else:
                out, nssm = ssm_lib.mamba_decode(
                    bp["mamba"], bc["ssm"],
                    norm(cfg.norm, bp["norm1"], h), pos, cfg)
                h = h + out
                nc = {"ssm": nssm}
                if kind == "mamba_attn" and shared is not None:
                    out, nkv = attn.gqa_decode(
                        shared["attn"], bc["shared"],
                        norm(cfg.norm, shared["norm1"], h), pos, cfg,
                        layer_kind="attn", long_mode=long_mode)
                    h = h + out
                    h = h + mlp(shared["mlp"],
                                norm(cfg.norm, shared["norm2"], h),
                                activation=cfg.activation)
                    nc["shared"] = nkv
                new_cache[f"b{i}"] = nc
        return h, new_cache

    h, new_caches = _scan(unit_fn, h, (params["units"], caches))
    h = norm(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = linear(params["lm_head"], h).astype(jnp.float32)
    return logits, new_caches


def prefill(params, tokens, cfg: ModelConfig, *, frontend_embeds=None,
            max_len: int | None = None):
    """Forward pass + cache construction for subsequent decode.

    Returns (last-position logits (B,1,V), caches, aux).  The caches come
    straight out of the forward pass (each block's post-rope K/V, MLA
    latents, or final SSM state), so ``decode_step`` continues exactly.
    ``max_len`` pads non-ring caches with decode headroom.
    """
    logits, aux, caches = forward(params, tokens, cfg,
                                  frontend_embeds=frontend_embeds,
                                  want_cache=True)
    if max_len is not None:
        S = tokens.shape[1]
        caches = _pad_caches(caches, S, max_len)
    return logits[:, -1:], caches, aux


def _pad_caches(caches, cur_len: int, max_len: int):
    """Pad full-length (non-ring) KV/MLA caches along the position axis.

    Cache leaves are stacked over units: (n_units, B, L, ...). Ring caches
    (L == window < cur_len) are left alone — decode masks by age.
    """
    def pad(x):
        L = x.shape[2]
        if L != cur_len or max_len <= L:
            return x  # ring buffer or already long enough
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, max_len - L)
        return jnp.pad(x, widths)

    def walk(c):
        if isinstance(c, attn.KVCache):
            return attn.KVCache(k=pad(c.k), v=pad(c.v))
        if isinstance(c, attn.MLACache):
            return attn.MLACache(c_kv=pad(c.c_kv), k_rope=pad(c.k_rope))
        if isinstance(c, ssm_lib.SSMCache):
            return c
        if isinstance(c, dict):
            return {k: walk(v) for k, v in c.items()}
        raise TypeError(type(c))

    return walk(caches)
