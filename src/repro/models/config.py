"""Architecture configuration schema.

One ``ModelConfig`` describes any architecture in the assigned pool: dense
GQA transformers, MLA, MoE, Mamba2/SSD, hybrids, and modality-stub decoders.
``src/repro/configs/<arch>.py`` instantiate these with the exact assigned
hyperparameters; ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    capacity_factor: float = 1.25
    impl: Literal["dense", "capacity"] = "capacity"
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64
    absorb: bool = False           # absorbed decode matmuls (§Perf)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // n_heads
    # attention pattern
    attention: Literal["full", "sliding", "local_global", "mla", "none"] = "full"
    window: int = 4096                     # sliding-window length
    local_global_ratio: int = 5            # N local layers per 1 global
    # positions
    rope: Literal["standard", "partial", "mrope", "none"] = "standard"
    rope_theta: float = 10000.0
    rope_theta_local: float | None = None  # gemma3: separate local theta
    rotary_pct: float = 1.0                # partial rotary fraction (chatglm)
    # blocks
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    activation: Literal["swiglu", "geglu", "gelu", "silu"] = "swiglu"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0                    # hybrid: shared attn every N layers
    shared_attention: bool = False         # hybrid: attn params shared
    # modality stub
    frontend: Literal["none", "vision", "audio"] = "none"
    frontend_tokens: int = 0               # stub embedding positions
    # long-context substitution (DESIGN.md §4)
    long_context: Literal["native", "sliding_window"] = "native"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # §Perf knob: constrain inter-block activations to stay model-sharded on
    # d_model (GSPMD then reshards with gather/reduce-scatter pairs around
    # each block instead of keeping replicated activations)
    activation_sharding: bool = False
    # citation
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'attn_global' | 'attn_local' |
        'mamba' | 'mamba_attn' (hybrid layer with shared attention)."""
        kinds = []
        for i in range(self.n_layers):
            if self.arch_type == "ssm":
                kinds.append("mamba")
            elif self.arch_type == "hybrid":
                if self.attn_every and (i + 1) % self.attn_every == 0:
                    kinds.append("mamba_attn")
                else:
                    kinds.append("mamba")
            elif self.attention == "local_global":
                r = self.local_global_ratio
                kinds.append("attn_global" if (i + 1) % (r + 1) == 0
                             else "attn_local")
            else:
                kinds.append("attn")
        return kinds

    def unit_pattern(self) -> tuple[list[str], int]:
        """(pattern, n_units): layers = pattern * n_units; scan over units."""
        kinds = self.layer_kinds()
        # find the smallest repeating pattern that tiles the layer list
        for plen in range(1, len(kinds) + 1):
            if len(kinds) % plen:
                continue
            if kinds == kinds[: plen] * (len(kinds) // plen):
                return kinds[: plen], len(kinds) // plen
        return kinds, 1

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind.startswith("attn"):
                per = self._attn_params() + self._ffn_params()
            elif kind == "mamba":
                per = self._mamba_params()
            elif kind == "mamba_attn":
                per = self._mamba_params()
            per_layer += per + 2 * d  # norms
        n += per_layer
        if self.shared_attention and self.arch_type == "hybrid":
            n += self._attn_params() + self._ffn_params() + 2 * self.d_model
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        if self.attention == "mla":
            m = self.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            e = self.moe
            gates = 3 if self.activation in ("swiglu", "geglu") else 2
            return d * e.n_experts + e.n_experts * gates * d * e.d_expert
        gates = 3 if self.activation in ("swiglu", "geglu") else 2
        return gates * d * self.d_ff

    def _mamba_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_in = s.expand * d
        nh = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        return (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
                + conv_dim * s.d_conv + nh + nh + d_in            # conv,A,D,nrm
                + d_in * d)                                       # out_proj

    def reduced(self, *, n_layers=2, d_model=256, n_experts=4,
                vocab=512, d_ff=None) -> "ModelConfig":
        """CPU smoke-test variant of the same family."""
        heads = max(2, min(self.n_heads, d_model // 64))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes: dict = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            d_ff=d_ff or (2 * d_model if self.d_ff else 0),
            vocab_size=vocab,
            head_dim=64,
            window=min(self.window, 64),
            frontend_tokens=min(self.frontend_tokens, 16),
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=n_experts,
                top_k=min(self.moe.top_k, n_experts),
                d_expert=2 * d_model, impl="dense")
        if self.mla is not None:
            changes["mla"] = dataclasses.replace(
                self.mla, q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=16)
        if self.arch_type == "hybrid":
            changes["attn_every"] = 2
            changes["n_layers"] = 4
        if self.attention == "local_global":
            changes["local_global_ratio"] = 1
            changes["n_layers"] = 4
        return dataclasses.replace(self, **changes)
