"""Attention blocks: GQA (full / sliding-window / local:global) and MLA.

Conventions
-----------
* Training / prefill forward: ``(B, S, d_model)`` activations, query-block
  *chunked* attention so the score matrix never materialises at more than
  ``(chunk_q, S_kv)`` per head — required for 32k prefill at production batch.
* Sliding-window layers slice K/V to the live window per query chunk, so
  compute is O(S * window), not O(S^2).
* Decode: one query token against a KV cache.  Full-attention layers keep a
  linear cache of ``seq_len``; sliding-window layers keep a ring buffer of
  ``window`` slots (this is what makes long_500k decodable for windowed
  configs — DESIGN.md §4).
* MLA (MiniCPM3/DeepSeek-style) caches the compressed latent ``c_kv`` and the
  shared rope key only: cache bytes per token = kv_lora_rank + rope_dim,
  ~18x smaller than GQA at the same d_model.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import rope as rope_lib
from .config import ModelConfig
from .layers import linear, linear_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# =========================================================== GQA attention ==

def gqa_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": linear_init(k1, d, cfg.n_heads * hd, dtype=cfg.pdtype,
                          bias=cfg.qkv_bias),
        "wk": linear_init(k2, d, cfg.n_kv_heads * hd, dtype=cfg.pdtype,
                          bias=cfg.qkv_bias),
        "wv": linear_init(k3, d, cfg.n_kv_heads * hd, dtype=cfg.pdtype,
                          bias=cfg.qkv_bias),
        "wo": linear_init(k4, cfg.n_heads * hd, d, dtype=cfg.pdtype),
    }


def _apply_positions(cfg: ModelConfig, q, k, positions, *, layer_kind: str):
    theta = cfg.rope_theta
    if layer_kind == "attn_local" and cfg.rope_theta_local is not None:
        theta = cfg.rope_theta_local
    if cfg.rope == "none":
        return q, k
    if cfg.rope == "mrope":
        if positions.ndim == 2:  # (B,S) text-only -> degenerate 3-stream
            positions = jnp.stack([positions] * 3, axis=0)
        return rope_lib.mrope(q, k, positions, theta=theta,
                              sections=_mrope_sections(cfg))
    rd = int(cfg.hd * cfg.rotary_pct)
    rd -= rd % 2
    return rope_lib.standard_rope(q, k, positions, theta=theta,
                                  rotary_dim=rd)


def _mrope_sections(cfg: ModelConfig):
    # pairs summing to hd/2 in 1:1.5:1.5 t/h/w split (qwen2-vl uses 16/24/24
    # for hd=128)
    half = cfg.hd // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def _chunked_scores_softmax(qc, k, v, mask):
    """qc: (B,C,KH,G,Dh); k/v: (B,Skv,KH,Dh); mask: (B,C,Skv) or (C,Skv).

    Inputs stay in the compute dtype (bf16) with f32 ACCUMULATION
    (preferred_element_type) — casting the inputs to f32 would make every
    attention cotangent f32 and double the dominant backward all-reduce
    traffic (§Perf iteration 2).  Returns (B,C,KH,G,Dh) f32.
    """
    scale = qc.shape[-1] ** -0.5
    s = jnp.einsum("bckgd,bskd->bckgs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bckgs,bskd->bckgd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def gqa_forward(params, x, positions, cfg: ModelConfig, *,
                layer_kind: str = "attn", chunk_q: int = 512,
                return_kv: bool = False):
    """Training/prefill GQA attention. x: (B,S,d). Returns (B,S,d)
    (and the layer's KVCache when ``return_kv``)."""
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KH
    q = linear(params["wq"], x).reshape(B, S, H, hd)
    k = linear(params["wk"], x).reshape(B, S, KH, hd)
    v = linear(params["wv"], x).reshape(B, S, KH, hd)
    q, k = _apply_positions(cfg, q, k, positions, layer_kind=layer_kind)
    windowed = layer_kind == "attn_local" or cfg.attention == "sliding"
    window = cfg.window if windowed else None

    C = min(chunk_q, S)
    while S % C:
        C -= 1
    n_chunks = S // C
    qs = q.reshape(B, n_chunks, C, KH, G, hd)

    kv_pos = jnp.arange(S)

    def one_chunk(ci, qc):
        q_pos = ci * C + jnp.arange(C)
        if window is not None and window + C < S:
            # slice K/V to [chunk_start - window, chunk_start + C)
            kw = window + C
            start = jnp.clip(ci * C - window, 0, S - kw)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kw, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kw, axis=1)
            kp = start + jnp.arange(kw)
        else:
            ks, vs, kp = k, v, kv_pos
        mask = kp[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kp[None, :] > q_pos[:, None] - window
        return _chunked_scores_softmax(qc, ks, vs, mask)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n_chunks), jnp.moveaxis(qs, 0, 1)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H * hd).astype(x.dtype)
    out = linear(params["wo"], out)
    if return_kv:
        L = min(cfg.window, S) if windowed else S
        kc, vc = k[:, S - L:], v[:, S - L:]
        if windowed and L < S:
            # ring alignment: entry for absolute pos p lives at slot p % L
            shift = (S - L) % L
            kc = jnp.roll(kc, shift, axis=1)
            vc = jnp.roll(vc, shift, axis=1)
        return out, KVCache(k=kc.astype(cfg.cdtype), v=vc.astype(cfg.cdtype))
    return out


class KVCache(NamedTuple):
    k: jax.Array        # (B, L, KH, hd) — L = seq_len, or window (ring)
    v: jax.Array


def _is_windowed(cfg: ModelConfig, layer_kind: str, long_mode: bool) -> bool:
    return (layer_kind == "attn_local" or cfg.attention == "sliding"
            or (long_mode and cfg.long_context == "sliding_window"))


def gqa_init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
                   layer_kind: str = "attn", long_mode: bool = False):
    windowed = _is_windowed(cfg, layer_kind, long_mode)
    L = min(cfg.window, seq_len) if windowed else seq_len
    shape = (batch, L, cfg.n_kv_heads, cfg.hd)
    return KVCache(k=jnp.zeros(shape, cfg.cdtype),
                   v=jnp.zeros(shape, cfg.cdtype))


def gqa_decode(params, cache: KVCache, x, pos, cfg: ModelConfig, *,
               layer_kind: str = "attn", long_mode: bool = False):
    """One-token decode. x: (B,1,d); pos: scalar int32 (current position).

    Windowed layers use the cache as a ring buffer (L == window slots), so
    cache memory is O(window) regardless of sequence length.
    Returns (out (B,1,d), new_cache).
    """
    B = x.shape[0]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KH
    q = linear(params["wq"], x).reshape(B, 1, H, hd)
    k = linear(params["wk"], x).reshape(B, 1, KH, hd)
    v = linear(params["wv"], x).reshape(B, 1, KH, hd)
    rpos = pos
    if cfg.rope == "mrope":
        from .multimodal import mrope_text_position
        rpos = mrope_text_position(cfg, pos)
    positions = jnp.full((B, 1), rpos, jnp.int32)
    q, k = _apply_positions(cfg, q, k, positions, layer_kind=layer_kind)

    L = cache.k.shape[1]
    windowed = _is_windowed(cfg, layer_kind, long_mode)
    slot = jnp.mod(pos, L) if windowed else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                             slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                             slot, axis=1)
    idx = jnp.arange(L)
    if windowed:
        # slot i holds absolute position pos - ((slot - i) mod L)
        age = jnp.mod(slot - idx, L)
        valid = ((pos - age) >= 0) & (age < cfg.window)
    else:
        valid = idx <= pos
    qg = q.reshape(B, KH, G, hd)
    s = jnp.einsum("bkgd,blkd->bkgl", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkd->bkgd", p, cv.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    return linear(params["wo"], o), KVCache(k=ck, v=cv)


# =========================================================== MLA attention ==

def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": linear_init(ks[0], d, m.q_lora_rank, dtype=cfg.pdtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype=cfg.pdtype),
        "wq_b": linear_init(ks[1], m.q_lora_rank, H * qd, dtype=cfg.pdtype),
        "wkv_a": linear_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                             dtype=cfg.pdtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype=cfg.pdtype),
        "wkv_b": linear_init(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim),
                             dtype=cfg.pdtype),
        "wo": linear_init(ks[4], H * m.v_head_dim, d, dtype=cfg.pdtype),
    }


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = linear(params["wq_b"], rmsnorm(params["q_norm"],
                                       linear(params["wq_a"], x)))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv_a = linear(params["wkv_a"], x)
    c_kv = rmsnorm(params["kv_norm"], kv_a[..., : m.kv_lora_rank])
    k_rope = kv_a[..., m.kv_lora_rank:].reshape(B, S, 1, dr)
    q_rope, k_rope = rope_lib.standard_rope(q_rope, k_rope, positions,
                                            theta=cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params, c_kv, cfg: ModelConfig):
    m = cfg.mla
    H = cfg.n_heads
    dn, dv = m.qk_nope_head_dim, m.v_head_dim
    kv = linear(params["wkv_b"], c_kv)
    kv = kv.reshape(*c_kv.shape[:-1], H, dn + dv)
    return kv[..., :dn], kv[..., dn:]


def mla_forward(params, x, positions, cfg: ModelConfig, *, chunk_q: int = 512,
                return_kv: bool = False, **_):
    B, S, _ = x.shape
    m = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)
    k_nope, v = _mla_expand_kv(params, c_kv, cfg)   # (B,S,H,dn), (B,S,H,dv)
    scale = (dn + dr) ** -0.5
    C = min(chunk_q, S)
    while S % C:
        C -= 1
    n_chunks = S // C

    def one_chunk(ci, qn_c, qr_c):
        q_pos = ci * C + jnp.arange(C)
        s = (jnp.einsum("bchd,bshd->bchs", qn_c, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bchd,bsxd->bchs", qr_c,
                          jnp.broadcast_to(k_rope, (B, S, 1, dr)),
                          preferred_element_type=jnp.float32)) * scale
        mask = jnp.arange(S)[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bchs,bshd->bchd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    qn = jnp.moveaxis(q_nope.reshape(B, n_chunks, C, H, dn), 0, 1)
    qr = jnp.moveaxis(q_rope.reshape(B, n_chunks, C, H, dr), 0, 1)
    out = jax.lax.map(lambda a: one_chunk(*a), (jnp.arange(n_chunks), qn, qr))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H * dv).astype(x.dtype)
    out = linear(params["wo"], out)
    if return_kv:
        return out, MLACache(c_kv=c_kv.astype(cfg.cdtype),
                             k_rope=k_rope[:, :, 0].astype(cfg.cdtype))
    return out


class MLACache(NamedTuple):
    c_kv: jax.Array     # (B, L, kv_lora_rank)
    k_rope: jax.Array   # (B, L, rope_dim)


def mla_init_cache(cfg: ModelConfig, batch: int, seq_len: int, **_):
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, seq_len, m.kv_lora_rank), cfg.cdtype),
        k_rope=jnp.zeros((batch, seq_len, m.qk_rope_head_dim), cfg.cdtype),
    )


def mla_decode(params, cache: MLACache, x, pos, cfg: ModelConfig, **_):
    B = x.shape[0]
    m = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)
    cc = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), pos, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope[:, :, 0].astype(cache.k_rope.dtype), pos, axis=1)
    L = cc.shape[1]
    valid = jnp.arange(L) <= pos
    scale = (dn + dr) ** -0.5
    if m.absorb:
        # Absorbed decode (§Perf): score = (q_nope @ Wkn^T) . c  + q_rope . kr
        # Wkv_b: (rank, H*(dn+dv)) -> Wkn: (rank, H, dn), Wv: (rank, H, dv)
        wkv = params["wkv_b"]["w"].reshape(m.kv_lora_rank, H, dn + dv)
        wkn, wv = wkv[..., :dn], wkv[..., dn:]
        q_abs = jnp.einsum("bohd,rhd->bohr", q_nope.astype(jnp.float32),
                           wkn.astype(jnp.float32))  # (B,1,H,rank)
        s = (jnp.einsum("bohr,blr->bhl", q_abs, cc.astype(jnp.float32))
             + jnp.einsum("bohd,bld->bhl", q_rope.astype(jnp.float32),
                          cr.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhl,blr->bhr", p, cc.astype(jnp.float32))
        o = jnp.einsum("bhr,rhd->bhd", ctx, wv.astype(jnp.float32))
    else:
        k_nope, v = _mla_expand_kv(params, cc, cfg)  # (B,L,H,dn/dv)
        s = (jnp.einsum("bohd,blhd->bhl", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
             + jnp.einsum("bohd,bld->bhl", q_rope[:, :, :, :].astype(
                 jnp.float32), cr.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhl,blhd->bhd", p, v.astype(jnp.float32))
    o = o.reshape(B, 1, H * dv).astype(x.dtype)
    return linear(params["wo"], o), MLACache(c_kv=cc, k_rope=cr)
