from . import attention, config, layers, model, moe, multimodal, rope, ssm
from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from .model import (abstract_params, decode_step, forward, init_caches,
                    init_params, loss_fn, prefill)

__all__ = [
    "attention", "config", "layers", "model", "moe", "multimodal", "rope",
    "ssm", "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig",
    "abstract_params", "decode_step", "forward", "init_caches",
    "init_params", "loss_fn", "prefill",
]
