"""Basic NN layers: norms, projections, gated MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays; every layer is an
(init, apply) pair of pure functions so the whole model remains a pytree
that DGS can sparsify leaf-by-leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- linear --

def linear_init(key, d_in: int, d_out: int, *, dtype=jnp.float32,
                bias: bool = False):
    p = {"w": _normal(key, (d_in, d_out), d_in ** -0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    """Matmul in the activation dtype (params cast at use: bf16 compute
    against f32 master weights, the standard mixed-precision recipe)."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ------------------------------------------------------------------ norms --

def rmsnorm_init(d: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm_init(kind: str, d: int, *, dtype=jnp.float32):
    return (rmsnorm_init if kind == "rmsnorm" else layernorm_init)(d, dtype=dtype)


def norm(kind: str, p, x):
    return (rmsnorm if kind == "rmsnorm" else layernorm)(p, x)


# ------------------------------------------------------------------- mlps --

def mlp_init(key, d_model: int, d_ff: int, *, activation: str = "swiglu",
             dtype=jnp.float32, bias: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": linear_init(k1, d_model, d_ff, dtype=dtype, bias=bias),
        "down": linear_init(k2, d_ff, d_model, dtype=dtype, bias=bias),
    }
    if activation in ("swiglu", "geglu"):
        p["gate"] = linear_init(k3, d_model, d_ff, dtype=dtype, bias=bias)
    return p


def mlp(p, x, *, activation: str = "swiglu"):
    if activation == "swiglu":
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    elif activation == "geglu":
        h = jax.nn.gelu(linear(p["gate"], x)) * linear(p["up"], x)
    elif activation == "gelu":
        h = jax.nn.gelu(linear(p["up"], x))
    elif activation == "silu":
        h = jax.nn.silu(linear(p["up"], x))
    else:
        raise ValueError(activation)
    return linear(p["down"], h)


# -------------------------------------------------------------- embedding --

def embedding_init(key, vocab: int, d_model: int, *, dtype=jnp.float32):
    # d^-0.5 keeps tied-head logits O(1)
    return {"table": _normal(key, (vocab, d_model), d_model ** -0.5, dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied LM head: logits = x @ table.T (float32 for stable softmax)."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T
