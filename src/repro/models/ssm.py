"""Mamba2 block — SSD (state-space duality) form, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within a chunk of length Q
the recurrence is computed as a masked (semiseparable) matmul — MXU-friendly —
and chunks are chained by a short sequential scan over per-chunk states.
Decode is the O(1)-per-token recurrent update on a (B, H, P, N) state plus a
rolling conv window — this is what makes ``long_500k`` native for SSM/hybrid
architectures (no KV cache at all).

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim(P),
B/C projections per group (n_groups G), state size N = d_state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _normal, linear, linear_init, rmsnorm, rmsnorm_init


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim


def mamba_init(key, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh  # z, x, B, C, dt
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, d_proj, dtype=cfg.pdtype),
        "conv_w": _normal(ks[1], (s.d_conv, conv_dim),
                          s.d_conv ** -0.5, cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "dt_bias": jnp.zeros((nh,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.pdtype),
        "D": jnp.ones((nh,), cfg.pdtype),
        "norm": rmsnorm_init(d_in, dtype=cfg.pdtype),
        "out_proj": linear_init(ks[2], d_in, cfg.d_model, dtype=cfg.pdtype),
    }


def _split_proj(cfg, proj):
    s, d_in, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc_dt = proj[..., :d_in], proj[..., d_in:]
    xBC = xbc_dt[..., : d_in + 2 * gn]
    dt = xbc_dt[..., d_in + 2 * gn:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width K. xBC: (B,S,C); w: (K,C).

    Runs in the activation dtype (bf16): upcasting here makes the (B,S,C)
    TP gathers f32 and doubles their wire bytes (§Perf); the K=4-tap
    accumulation is benign in bf16.
    """
    K = w.shape[0]
    w = w.astype(xBC.dtype)
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i: i + xBC.shape[1], :] * w[i][None, None, :]
        for i in range(K)
    )
    return jax.nn.silu(out.astype(jnp.float32) + b[None, None, :].astype(
        jnp.float32))


def _segsum(dA):
    """dA: (..., Q) -> L (..., Q, Q): L[i,j] = exp(sum_{j<k<=i} dA_k), i>=j."""
    Q = dA.shape[-1]
    csum = jnp.cumsum(dA, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: exp of large positive upper-triangle entries would
    # overflow and poison gradients through the where
    return jnp.exp(jnp.where(tril, diff, -jnp.inf))


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int):
    """Chunked SSD scan.

    x:  (B, S, H, P)   inputs (pre dt-scaling)
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm: (B, S, G, N)   input projections (groups broadcast over heads)
    Cm: (B, S, G, N)   output projections
    Returns y: (B, S, H, P)
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    f32 = jnp.float32
    xdt = x.astype(f32) * dt[..., None].astype(f32)            # (B,S,H,P)
    dA = dt.astype(f32) * A.astype(f32)[None, None, :]          # (B,S,H)
    # chunked views
    xc = xdt.reshape(Bsz, nc, Q, H, P)
    dAc = dA.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, G, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, G, N).astype(f32)
    # broadcast groups over heads
    Bh = jnp.repeat(Bc, hpg, axis=3)                            # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, hpg, axis=3)
    dA_t = jnp.moveaxis(dAc, -1, 2)                             # (B,nc,H,Q)
    L = _segsum(dA_t)                                           # (B,nc,H,Q,Q)
    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)           # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * L, xc)
    # per-chunk final states: sum_s exp(sum_{s<k<=Q} dA) * B_s x_s
    csum = jnp.cumsum(dA_t, axis=-1)                            # (B,nc,H,Q)
    decay_states = jnp.exp(csum[..., -1:] - csum)               # (B,nc,H,Q)
    states = jnp.einsum("bchs,bcshn,bcshp->bchpn",
                        decay_states, Bh, xc)                   # (B,nc,H,P,N)
    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(csum[..., -1])                        # (B,nc,H)

    def scan_fn(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((Bsz, H, P, N), f32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # (B,nc,H,P,N)
    # off-diagonal contribution: y += C_l . exp(A_cum_l) state_prev
    in_decay = jnp.exp(csum)                                    # (B,nc,H,Q)
    y_off = jnp.einsum("bclhn,bchl,bchpn->bclhp", Ch, in_decay, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final_state


def mamba_forward(params, x, cfg: ModelConfig, *, return_state: bool = False,
                  **_):
    """x: (B, S, d_model) -> (B, S, d_model) (and SSMCache if requested)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    Bsz, S, _ = x.shape
    proj = linear(params["in_proj"], x)
    z, xBC_raw, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    gn = s.n_groups * s.d_state
    xs = xBC[..., :d_in].reshape(Bsz, S, nh, s.head_dim)
    Bm = xBC[..., d_in: d_in + gn].reshape(Bsz, S, s.n_groups, s.d_state)
    Cm = xBC[..., d_in + gn:].reshape(Bsz, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32)[None, None])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=s.chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32)
    y = y.reshape(Bsz, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(x.dtype))
    out = linear(params["out_proj"], y)
    if return_state:
        K = s.d_conv
        tail = xBC_raw[:, S - (K - 1):, :] if S >= K - 1 else jnp.pad(
            xBC_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        cache = SSMCache(state=final_state,
                         conv=tail.astype(cfg.cdtype))
        return out, cache
    return out


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, P, N) recurrent state
    conv: jax.Array        # (B, d_conv-1, conv_dim) rolling conv inputs


def mamba_init_cache(cfg: ModelConfig, batch: int, seq_len: int, **_):
    s, d_in, nh, conv_dim = _dims(cfg)
    return SSMCache(
        state=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), cfg.cdtype),
    )


def mamba_decode(params, cache: SSMCache, x, pos, cfg: ModelConfig, **_):
    """One-token recurrent step. x: (B, 1, d_model)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    Bsz = x.shape[0]
    proj = linear(params["in_proj"], x[:, 0])     # (B, d_proj)
    z, xBC, dt = _split_proj(cfg, proj)
    # rolling conv
    hist = jnp.concatenate(
        [cache.conv.astype(jnp.float32), xBC[:, None].astype(jnp.float32)],
        axis=1)                                    # (B, K, conv_dim)
    w = params["conv_w"].astype(jnp.float32)       # (K, conv_dim)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + params["conv_b"].astype(
        jnp.float32)
    xBC_c = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:].astype(cache.conv.dtype)
    gn = s.n_groups * s.d_state
    xs = xBC_c[..., :d_in].reshape(Bsz, nh, s.head_dim)
    Bm = xBC_c[..., d_in: d_in + gn].reshape(Bsz, s.n_groups, s.d_state)
    Cm = xBC_c[..., d_in + gn:].reshape(Bsz, s.n_groups, s.d_state)
    hpg = nh // s.n_groups
    Bh = jnp.repeat(Bm, hpg, axis=1)               # (B, H, N)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32)[None])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None])                  # (B, H)
    state = (cache.state * decay[..., None, None]
             + (dt[..., None] * xs)[..., :, None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(Bsz, d_in) * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(x.dtype))
    out = linear(params["out_proj"], y)[:, None, :]
    return out, SSMCache(state=state, conv=new_conv)
