"""Rotary position embeddings: standard 1-D, partial/2-D (ChatGLM), and
M-RoPE (Qwen2-VL multimodal 3-section), plus per-layer theta (Gemma 3 uses
10k for local layers and 1M for global layers).

All functions take/return (B, S, H, D) query/key tensors and are pure jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _rot_half_pairs(x):
    """Rotate pairs (x0,x1) -> (-x1, x0) over the last dim (interleaved)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    return jnp.stack([-x2, x1], axis=-1).reshape(x.shape)


def _freqs(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def _interleave2(x):
    """[a, b, ...] -> [a, a, b, b, ...] without jnp.repeat (repeat lowers to
    a gather, which trips XLA's SPMD gather partitioner under partial-manual
    shard_map at scale)."""
    return jnp.stack([x, x], axis=-1).reshape(*x.shape[:-1], -1)


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """cos/sin tables for given integer positions. -> (..., dim) each."""
    inv = jnp.asarray(_freqs(dim, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., dim/2)
    return _interleave2(jnp.cos(ang)), _interleave2(jnp.sin(ang))


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B,S,H,D); cos/sin: (B,S,D) or (S,D)."""
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return (x * cos + _rot_half_pairs(x) * sin).astype(x.dtype)


def standard_rope(q, k, positions, *, theta: float = 10000.0,
                  rotary_dim: int | None = None):
    """Standard RoPE over the first ``rotary_dim`` dims of the head.

    rotary_dim < head_dim gives ChatGLM-style partial ("2d") rotary: GLM
    applies rotation to half the head dims and leaves the rest untouched.
    """
    D = q.shape[-1]
    rd = rotary_dim or D
    cos, sin = rope_cos_sin(positions, rd, theta)
    if rd == D:
        return apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q_rot = apply_rope(q[..., :rd], cos, sin)
    k_rot = apply_rope(k[..., :rd], cos, sin)
    q = jnp.concatenate([q_rot, q[..., rd:]], axis=-1)
    k = jnp.concatenate([k_rot, k[..., rd:]], axis=-1)
    return q, k


def mrope(q, k, positions_tsw, *, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: the head dim is split into (temporal, height, width)
    sections, each rotated by its own position stream.

    positions_tsw: (3, B, S) int32 — per-token (t, h, w) position ids.  For
    pure text all three streams are equal and M-RoPE == RoPE.  ``sections``
    counts are in *pairs* (sum * 2 == rotary dim).
    """
    D = q.shape[-1]
    rd = 2 * sum(sections)
    assert rd <= D, (rd, D)
    inv = jnp.asarray(_freqs(rd, theta), dtype=jnp.float32)  # (rd/2,)
    # section id of each frequency pair
    sec = np.concatenate([
        np.full(s, i) for i, s in enumerate(sections)
    ])  # (rd/2,)
    pos = positions_tsw.astype(jnp.float32)  # (3, B, S)
    # pick position stream per pair
    ang = jnp.take(pos, jnp.asarray(sec), axis=0)            # (rd/2, B, S)
    ang = jnp.moveaxis(ang, 0, -1) * inv                     # (B, S, rd/2)
    cos = _interleave2(jnp.cos(ang))
    sin = _interleave2(jnp.sin(ang))
    q_rot = apply_rope(q[..., :rd], cos, sin)
    k_rot = apply_rope(k[..., :rd], cos, sin)
    if rd == D:
        return q_rot, k_rot
    return (jnp.concatenate([q_rot, q[..., rd:]], axis=-1),
            jnp.concatenate([k_rot, k[..., rd:]], axis=-1))


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Degenerate (text-only) M-RoPE position ids: all three streams equal."""
    p = jnp.broadcast_to(positions, positions.shape)
    return jnp.stack([p, p, p], axis=0)
