"""Mixture-of-Experts FFN: top-k router + two dispatch implementations.

* ``dense``    — every expert runs on every token, combined by router weight.
                 Exact (no token dropping); used by the reduced smoke configs
                 and as the correctness oracle for the capacity path.
* ``capacity`` — sort-based dispatch into a static (E, C, D) buffer
                 (C = top_k * T / E * capacity_factor); per-expert GEMMs are
                 one einsum; overflow tokens are dropped (standard practice).
                 This is the dry-run / production path: under GSPMD the
                 expert axis shards over "model" (expert parallelism) and the
                 token scatter/gather lowers to all-to-all style collectives.

Router aux losses: load-balance (Switch) + z-loss, returned for logging and
added to the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _normal, linear, linear_init


def moe_init(key, cfg: ModelConfig):
    e = cfg.moe
    d = cfg.d_model
    kr, k1, k2, k3 = jax.random.split(key, 4)
    gated = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": {"w": _normal(kr, (d, e.n_experts), d ** -0.5, cfg.pdtype)},
        "up": _normal(k1, (e.n_experts, d, e.d_expert),
                      d ** -0.5, cfg.pdtype),
        "down": _normal(k2, (e.n_experts, e.d_expert, d),
                        e.d_expert ** -0.5, cfg.pdtype),
    }
    if gated:
        p["gate"] = _normal(k3, (e.n_experts, d, e.d_expert),
                            d ** -0.5, cfg.pdtype)
    return p


def _expert_ffn(p, h, cfg: ModelConfig, *, expert_axis_in_front: bool):
    """h: (E, C, D) (capacity) or (T, E?, ...). Gated MLP per expert."""
    act = jax.nn.silu if cfg.activation in ("swiglu", "silu") else jax.nn.gelu
    dt = h.dtype
    if "gate" in p:
        g = jnp.einsum("ecd,edf->ecf", h, p["gate"].astype(dt))
        u = jnp.einsum("ecd,edf->ecf", h, p["up"].astype(dt))
        z = act(g) * u
    else:
        z = act(jnp.einsum("ecd,edf->ecf", h, p["up"].astype(dt)))
    return jnp.einsum("ecf,efd->ecd", z, p["down"].astype(dt))


def router_probs(p, x, cfg: ModelConfig):
    """x: (T, D) -> (probs (T,K), ids (T,K), aux losses dict)."""
    e = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, ids = jax.lax.top_k(probs_full, e.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise
    # Switch load-balance loss + router z-loss
    T = x.shape[0]
    density = jnp.mean(
        jax.nn.one_hot(ids[:, 0], e.n_experts, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs_full, axis=0)
    lb = e.n_experts * jnp.sum(density * mean_prob)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_p, ids, {"load_balance": lb, "router_z": z}


def moe_forward_dense(p, x, cfg: ModelConfig):
    """Exact dense-dispatch MoE. x: (B,S,D)."""
    e = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    top_p, ids, aux = router_probs(p, xf, cfg)
    # run all experts on all tokens: (E, T, D)
    h = jnp.broadcast_to(xf[None], (e.n_experts, xf.shape[0], D))
    out_all = _expert_ffn(p, h, cfg, expert_axis_in_front=True)  # (E,T,D)
    # combine selected experts
    w = jnp.zeros((xf.shape[0], e.n_experts), jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], ids
    ].add(top_p)
    out = jnp.einsum("te,etd->td", w.astype(out_all.dtype), out_all)
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_forward_capacity(p, x, cfg: ModelConfig):
    """Sort-based static-capacity MoE. x: (B,S,D)."""
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    top_p, ids, aux = router_probs(p, xf, cfg)
    K, E = e.top_k, e.n_experts
    C = max(1, int(round(T * K / E * e.capacity_factor)))
    # flatten (token, choice) pairs and sort by expert
    flat_e = ids.reshape(-1)                        # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)                     # stable
    e_s, t_s, p_s = flat_e[order], flat_t[order], flat_p[order]
    pos = jnp.arange(T * K, dtype=jnp.int32) - jnp.searchsorted(
        e_s, e_s, side="left").astype(jnp.int32)    # rank within expert
    keep = pos < C
    slot = jnp.where(keep, e_s * C + pos, E * C)    # drop -> OOB
    # dispatch via a SMALL index table + gather (not a (T*K, D) scatter):
    # scattering activations into the expert-sharded buffer makes XLA's SPMD
    # scatter partitioner replicate the whole buffer; gathering rows of the
    # data-sharded activations with an (E*C,) id table partitions as an
    # operand-passthrough gather — ~10x less data movement (§Perf, qwen3).
    tok_table = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        t_s, mode="drop")[: E * C]                  # empty slot -> pad row T
    xpad = jnp.concatenate([xf, jnp.zeros((1, D), x.dtype)], axis=0)
    buf = xpad[tok_table]
    out_buf = _expert_ffn(p, buf.reshape(E, C, D), cfg,
                          expert_axis_in_front=True).reshape(E * C, D)
    # combine back: gather slot outputs, weight, segment-sum over K choices
    contrib = jnp.where(keep[:, None], out_buf[jnp.minimum(slot, E * C - 1)],
                        0.0) * p_s[:, None].astype(out_buf.dtype)
    out = jnp.zeros((T, D), out_buf.dtype).at[t_s].add(contrib)
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_forward(p, x, cfg: ModelConfig):
    if cfg.moe.impl == "dense":
        return moe_forward_dense(p, x, cfg)
    return moe_forward_capacity(p, x, cfg)
