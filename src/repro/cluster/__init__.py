"""repro.cluster — the federated client/coordinator runtime.

Submodules (imported lazily to keep ``repro.core`` <-> ``repro.cluster``
dependencies one-directional at import time; ``core.async_sim`` pulls in
``cluster.wire`` inside functions only):

* ``wire``        — packed binary codec + measured byte accounting
* ``transport``   — Transport protocol: in-process hub, TCP sockets
* ``coordinator`` — the parameter-server side of the async loop
* ``client``      — the worker side
* ``scenarios``   — federated knobs: plans, participation, Dirichlet shards
* ``runner``      — assemble coordinator + clients in one process
* ``subscribe``   — serve leg: per-subscriber residual arenas + DIFF frames
* ``replica``     — the inference replica loop (decode while training)
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("wire", "transport", "coordinator", "client", "scenarios",
               "runner", "subscribe", "replica")

__all__ = list(_SUBMODULES) + ["run_inprocess"]


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    if name == "run_inprocess":
        return importlib.import_module(".runner", __name__).run_inprocess
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
