"""The coordinator: core/server.py's model-difference state behind a wire.

One asynchronous PS loop over any :mod:`repro.cluster.transport` backend.
Per upward message the coordinator runs the SAME jitted server stages as
``async_sim.AsyncTrainer`` (``make_server_step`` / ``make_commit``), with
the wire codec between them:

    UP frame  -> decode -> receive + send_select (jit)
              -> encode DOWN (codec quantizes values in-flight)
              -> send_commit with the codec's *shipped* leaves
              -> DOWN frame

so the server's v_k always tracks exactly the bits the client decoded, and
a schedule-driven run reproduces the simulator bit-for-bit.

Federated behaviours:

* elastic membership — HELLO assigns a worker slot (reusing freed slots,
  growing ``v`` via ``ps.add_worker`` when none are free); BYE zeroes the
  slot for the next joiner.
* partial participation — SKIP frames advance a client's virtual clock
  without touching server state.
* at-least-once delivery — duplicate UP ``seq`` numbers (client retries
  after a dropped frame) are answered from a per-client reply cache
  without re-applying the gradient.
* measured bytes — ``History.up_bytes``/``down_bytes`` are the actual
  serialized frame sizes moved through the transport.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import async_sim, engine as engine_lib
from repro.core import server as ps
from repro.core.engine import CompressionSpec

from . import wire
from .transport import RecvTimeout

AUTO_SLOT = 0xFFFFFFFF


@dataclasses.dataclass
class Coordinator:
    """Parameter-server side of the cluster runtime."""

    transport: Any
    params0: Any
    n_slots: int
    secondary_density: float | None = None
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC
    scheduler: Any = None              # ScheduleDriven | VirtualClock | None
    virtual_costs: dict | None = None  # client -> FaultPolicy (virtual time)
    recv_timeout: float | None = None

    def __post_init__(self):
        self.sstate = ps.init(self.params0, self.n_slots)
        self._server_step = async_sim.make_server_step(
            self.secondary_density, self.secondary_spec)
        self._commit = async_sim.make_commit()
        self._down_mode = self.secondary_spec.quantize
        # arena frame segmentation of the sparse downward message (None =
        # dense downward, framed DENSE/DENSE_COO)
        self._down_seg = (self.sstate.space.ks(self.secondary_density)
                          if self.secondary_density is not None else None)
        self._free = list(range(self.n_slots))
        self._slot_of: dict[int, int] = {}
        self._last_seq: dict[int, int] = {}
        self._reply_cache: dict[int, bytes] = {}
        self._joined: set[int] = set()
        self._left: set[int] = set()
        self._losses: list[float] = []
        self._served_slots: list[int] = []
        self._staleness: list[int] = []
        self._last_sync: dict[int, int] = {}
        self.up_bytes = 0
        self.down_bytes = 0

    # -- membership --------------------------------------------------------

    def _attach(self, client: int, proposed: int) -> int:
        if proposed != AUTO_SLOT and proposed in self._free:
            self._free.remove(proposed)
            slot = proposed
        elif self._free:
            slot = self._free.pop(0)
        else:
            self.sstate, slot = ps.add_worker(self.sstate)
            # v grew a row: the jitted server stages specialize on shapes,
            # so they recompile on the next event — correctness unaffected
        self._slot_of[client] = slot
        self._last_seq[client] = -1
        self._joined.add(client)
        self._last_sync.setdefault(slot, 0)
        return slot

    def _detach(self, client: int):
        slot = self._slot_of.pop(client, None)
        if slot is not None:
            self.sstate = ps.reset_worker(self.sstate, slot)
            self._free.append(slot)
            self._last_sync.pop(slot, None)
        self._left.add(client)
        if self.scheduler is not None:
            self.scheduler.deactivate(client)

    # -- one message -------------------------------------------------------

    def _handle(self, src: int, payload: bytes) -> str:
        try:
            msg = wire.decode_message(payload)
        except Exception:
            if self.scheduler is not None:
                raise   # trusted in-process peers: corruption is a bug
            return "ignored"   # TCP: drop the malformed frame, keep serving
        if msg.type == wire.HELLO:
            slot = self._attach(src, msg.seq)
            reply, _ = wire.encode_message(
                wire.WELCOME, wire.COORDINATOR_ID, slot)
            self.transport.send(src, reply)
            return "hello"
        if msg.type == wire.SKIP:
            self._account(src, 0)
            return "skip"
        if msg.type == wire.BYE:
            self._detach(src)
            return "bye"
        if msg.type != wire.UP:
            raise ValueError(f"unexpected {wire.TYPE_NAMES[msg.type]}")
        if len(msg.leaves) != 1:
            # the arena protocol ships exactly ONE frame per UP message
            return "ignored"
        if src not in self._slot_of:
            # UP without a completed HELLO (restarted or foreign peer):
            # reject the frame, not the whole run
            return "ignored"

        if msg.seq <= self._last_seq.get(src, -1):
            # duplicate after a dropped reply: answer from cache, do NOT
            # re-apply the gradient (at-least-once -> exactly-once)
            cached = self._reply_cache.get(src)
            if cached is not None:
                self.transport.send(src, cached)
            return "dup"

        slot = self._slot_of[src]
        self.up_bytes += len(payload)
        e = len(self._losses)
        self._losses.append(float(np.float32(msg.aux)))
        self._served_slots.append(slot)
        self._staleness.append(e - self._last_sync.get(slot, 0))
        self._last_sync[slot] = e + 1

        self.sstate, G_raw = self._server_step(
            self.sstate, msg.leaves[0], jnp.int32(slot))
        reply, shipped = wire.encode_message(
            wire.DOWN, wire.COORDINATOR_ID, msg.seq, [G_raw],
            mode=self._down_mode, seg=self._down_seg)
        self.sstate = self._commit(self.sstate, jnp.int32(slot),
                                   shipped[0])
        self.down_bytes += len(reply)
        self._last_seq[src] = msg.seq
        self._reply_cache[src] = reply
        self.transport.send(src, reply)
        self._account(src, len(payload) + len(reply))
        return "up"

    def _account(self, client: int, nbytes: int):
        if self.scheduler is None:
            return
        cost = 0.0
        if self.virtual_costs and client in self.virtual_costs and nbytes:
            cost = self.virtual_costs[client].frame_cost(nbytes)
        self.scheduler.account(client, cost)

    # -- the loop ----------------------------------------------------------

    def serve(self, max_events: int | None = None):
        """Run until the schedule is exhausted / every client left.

        With a scheduler, each turn serves the scheduler's chosen client
        (selective receive — arrival order cannot change the served order).
        Without one (real-time TCP mode) messages are served as they come.
        """
        events = 0
        while max_events is None or events < max_events:
            who = None
            if self.scheduler is not None:
                who = self.scheduler.next_client()
                if who is None:
                    break
            # a turn absorbs control traffic until it yields at most one UP
            while True:
                try:
                    src, payload = self.transport.recv(
                        who, timeout=self.recv_timeout)
                except RecvTimeout:
                    if self.scheduler is None and self._all_done():
                        return self._finish()
                    raise
                kind = self._handle(src, payload)
                if kind == "up":
                    events += 1
                    break
                if kind in ("skip", "bye"):
                    break
                # hello/dup: keep this turn open
            if self.scheduler is None and self._all_done():
                break
        return self._finish()

    def _all_done(self) -> bool:
        return bool(self._joined) and self._joined <= self._left

    def _finish(self):
        final = ps.global_model(self.params0, self.sstate)
        hist = async_sim.History(
            losses=np.asarray(self._losses, np.float64),
            worker_ids=np.asarray(self._served_slots, np.int32),
            staleness=np.asarray(self._staleness, np.int64),
            up_bytes=self.up_bytes,
            down_bytes=self.down_bytes,
            evals=[],
        )
        return final, hist
