"""The coordinator: core/server.py's model-difference state behind a wire.

One asynchronous PS loop over any :mod:`repro.cluster.transport` backend.
Per upward message the coordinator runs the SAME jitted server stages as
``async_sim.AsyncTrainer`` (``make_server_step`` / ``make_commit``), with
the wire codec between them:

    UP frame  -> decode -> receive + send_select (jit)
              -> encode DOWN (codec quantizes values in-flight)
              -> send_commit with the codec's *shipped* leaves
              -> DOWN frame

so the server's v_k always tracks exactly the bits the client decoded, and
a schedule-driven run reproduces the simulator bit-for-bit.

Federated behaviours:

* elastic membership — HELLO assigns a worker slot (reusing freed slots,
  growing ``v`` via ``ps.add_worker`` when none are free); BYE zeroes the
  slot for the next joiner.
* partial participation — SKIP frames advance a client's virtual clock
  without touching server state.
* at-least-once delivery — duplicate UP ``seq`` numbers (client retries
  after a dropped frame) are answered from a per-client reply cache
  without re-applying the gradient.
* measured bytes — ``History.up_bytes``/``down_bytes`` are the actual
  serialized frame sizes moved through the transport.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import async_sim, engine as engine_lib
from repro.core import server as ps
from repro.core.engine import CompressionSpec
from repro.telemetry import metrics as metrics_lib

from . import subscribe, wire
from .transport import RecvTimeout

AUTO_SLOT = 0xFFFFFFFF


@dataclasses.dataclass
class Coordinator:
    """Parameter-server side of the cluster runtime."""

    transport: Any
    params0: Any
    n_slots: int
    secondary_density: float | None = None
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC
    scheduler: Any = None              # ScheduleDriven | VirtualClock | None
    virtual_costs: dict | None = None  # client -> FaultPolicy (virtual time)
    recv_timeout: float | None = None
    # upper bound on how many scheduler turns drain as ONE batched server
    # dispatch (None = unbounded, 1 = serve serially).  Only schedulers
    # exposing ``next_batch`` (ScheduleDriven) batch; the batched stages
    # are bit-equal to the serial ones, so this is purely a perf knob.
    max_batch: int | None = None
    recorder: Any = None               # telemetry.Recorder (None = no-op)
    # sharded parameter server (DESIGN.md §12): this coordinator owns the
    # contiguous arena range shard_spec.bounds[shard_id:shard_id+2].  Its
    # ServerState, stages, seg tables, and wire frames are all over THAT
    # sub-arena — everything below runs unchanged because a leaf-aligned
    # shard is itself a complete (smaller) parameter arena.
    shard_spec: Any = None             # paramspace.ShardSpec | None
    shard_id: int = 0
    # device-mesh sharded server (DESIGN.md §14): ONE coordinator hosts
    # all S shard arenas as a stacked MeshServerState and serves them
    # through the in-graph alltoallv mesh stages — no shard threads, no
    # per-shard wire frames, so up/down bytes equal the single-server
    # reference exactly (the S-thread runtime pays S envelopes instead).
    # Mutually exclusive with shard_spec/shard_id above.
    mesh_shards: int = 0
    # serve leg (DESIGN.md §13): inference replicas SUBscribe and PULL
    # coalesced re-sparsified model-diffs while training runs.
    # ``push_density`` picks the per-tensor top-k of each push (None =
    # ship the exact nonzero residual); ``push_spec`` the engine + wire
    # quantization.  ``min_subscribers`` keeps the coordinator serving
    # until that many replicas have subscribed AND left — closing the race
    # where a short schedule quiesces before TCP replicas connect.
    push_density: float | None = None
    push_spec: CompressionSpec = engine_lib.EXACT_SPEC
    min_subscribers: int = 0
    # delta-checkpoints (checkpoint/delta.py): append the live arena
    # every ``ckpt_every`` served events (0 = final state only)
    ckpt_dir: Any = None
    ckpt_every: int = 0

    def __post_init__(self):
        if self.recorder is None:
            self.recorder = telemetry.NULL
        if self.shard_spec is not None:
            leaves = jax.tree.leaves(self.params0)
            self._params0_local = self.shard_spec.shard_leaves(
                leaves, self.shard_id)
        else:
            self._params0_local = self.params0
        if self.mesh_shards:
            if self.shard_spec is not None:
                raise ValueError("mesh_shards and shard_spec are two "
                                 "different sharding runtimes — pass one")
            self.sstate = ps.init_mesh_shards(
                self._params0_local, self.n_slots, self.mesh_shards)
            self._batched_server = async_sim.make_mesh_batched_server_step(
                self.secondary_density, self.secondary_spec)
            self._commit_rows = async_sim.make_mesh_batched_commit(
                self.secondary_density is None)
        else:
            self.sstate = ps.init(self._params0_local, self.n_slots)
            self._batched_server = async_sim.make_batched_server_step(
                self.secondary_density, self.secondary_spec)
            self._commit_rows = async_sim.make_batched_commit(
                self.secondary_density is None)
        self._down_mode = self.secondary_spec.quantize
        # arena frame segmentation of the sparse downward message (None =
        # dense downward, framed DENSE/DENSE_COO)
        self._down_seg = (self.sstate.space.ks(self.secondary_density)
                          if self.secondary_density is not None else None)
        self._free = list(range(self.n_slots))
        self._slot_of: dict[int, int] = {}
        self._last_seq: dict[int, int] = {}
        self._reply_cache: dict[int, bytes] = {}
        self._joined: set[int] = set()
        self._left: set[int] = set()
        self._losses: list[float] = []
        self._served_slots: list[int] = []
        self._staleness: list[int] = []
        self._last_sync: dict[int, int] = {}
        self.up_bytes = 0
        self.down_bytes = 0
        # flight-recorder accounting: message-kind + per-client counters
        # and per-event frame sizes for the run-report histograms.  All
        # host-side ints — nothing here touches the jitted server stages.
        self.counters: dict[str, float] = {}
        self._up_sizes: list[int] = []
        self._down_sizes: list[int] = []
        # the shard-balance table's size column: how much of the arena
        # (and therefore of M / each v row) this coordinator holds.  A
        # mesh coordinator hosts EVERY shard, so it emits all S rows.
        if self.mesh_shards:
            for s, sz in enumerate(self.sstate.spec.sizes):
                self.counters[f"shard/{s}/arena_elems"] = sz
        else:
            self.counters[f"shard/{self.shard_id}/arena_elems"] = \
                self.sstate.space.total
        # serve leg state: per-subscriber cursor arenas + the live-arena
        # delta-checkpoint chain.  theta0's arena is kept on the host so
        # checkpoint appends are a plain numpy add off the jit hot path.
        self.book = subscribe.SubscriberBook(
            self.sstate.space, push_density=self.push_density,
            push_spec=self.push_spec)
        self._training_over = False
        self._theta0_arena = np.asarray(
            self.sstate.space.pack(self._params0_local), np.float32)
        self._ckpt = None
        self._ckpt_last = 0
        if self.ckpt_dir is not None:
            from repro.checkpoint import DeltaCheckpointWriter
            self._ckpt = DeltaCheckpointWriter(
                self.ckpt_dir, self._theta0_arena, version=0,
                meta={"n_slots": self.n_slots, "shard_id": self.shard_id})

    def _count(self, name: str, n: float = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    # -- membership --------------------------------------------------------

    def _attach(self, client: int, proposed: int) -> int:
        if proposed != AUTO_SLOT and proposed in self._free:
            self._free.remove(proposed)
            slot = proposed
        elif self._free:
            slot = self._free.pop(0)
        else:
            self.sstate, slot = ps.add_worker(self.sstate)
            # v grew a row: the jitted server stages specialize on shapes,
            # so they recompile on the next event — correctness unaffected
        self._slot_of[client] = slot
        self._last_seq[client] = -1
        # a rejoining client id must not inherit the previous tenant's
        # cached reply (its seq numbers restart at 0)
        self._reply_cache.pop(client, None)
        self._joined.add(client)
        self._last_sync.setdefault(slot, 0)
        return slot

    def _detach(self, client: int):
        slot = self._slot_of.pop(client, None)
        if slot is not None:
            self.sstate = ps.reset_worker(self.sstate, slot)
            self._free.append(slot)
            self._last_sync.pop(slot, None)
        # bound the at-least-once dedup state: a departed client can never
        # retransmit, so its cached reply and seq watermark are garbage —
        # the cache holds at most one entry per LIVE client
        self._reply_cache.pop(client, None)
        self._last_seq.pop(client, None)
        self._left.add(client)
        if self.scheduler is not None:
            self.scheduler.deactivate(client)

    # -- one message -------------------------------------------------------

    def _classify(self, src: int, payload: bytes):
        """Decode + dispatch control traffic; returns ``(kind, msg)``.

        UP frames are only *validated* here — the gradient math runs in
        :meth:`_process_ups`, which takes a whole batch of them at once.
        """
        try:
            msg = wire.decode_message(payload)
        except Exception:
            if self.scheduler is not None:
                raise   # trusted in-process peers: corruption is a bug
            self._count("ignored")
            return "ignored", None  # TCP: drop the bad frame, keep serving
        if msg.type == wire.HELLO:
            slot = self._attach(src, msg.seq)
            reply, _ = wire.encode_message(
                wire.WELCOME, wire.COORDINATOR_ID, slot)
            self.transport.send(src, reply)
            self._count("hello")
            return "hello", msg
        if msg.type == wire.SKIP:
            self._account(src, 0)
            self._count("skip")
            return "skip", msg
        if msg.type == wire.BYE:
            self._detach(src)
            self._count("bye")
            return "bye", msg
        if msg.type in (wire.SUB, wire.PULL, wire.SYNC):
            self._subscriber_msg(src, msg)
            return "sub", msg
        if msg.type != wire.UP:
            raise ValueError(f"unexpected {wire.TYPE_NAMES[msg.type]}")
        if len(msg.leaves) != 1:
            # the arena protocol ships exactly ONE frame per UP message
            self._count("ignored")
            return "ignored", None
        if src not in self._slot_of:
            # UP without a completed HELLO (restarted or foreign peer):
            # reject the frame, not the whole run
            self._count("ignored")
            return "ignored", None
        if msg.seq <= self._last_seq.get(src, -1):
            # duplicate after a dropped reply: answer from cache, do NOT
            # re-apply the gradient (at-least-once -> exactly-once)
            self._count("dup")
            self._count(f"client/{src}/dups")
            cached = self._reply_cache.get(src)
            if cached is not None:
                self._count("reply_cache_hits")
                self.transport.send(src, cached)
            return "dup", None
        return "up", msg

    def _process_ups(self, ups):
        """Apply a batch of UP messages as ONE pass over the server stages.

        ``ups`` is ``[(src, payload, msg), ...]`` with pairwise-distinct
        sources (the batching rule): the messages stack on a leading batch
        axis, the receives run as one scan, the select each raw downward
        message against its prefix M, and the commits fuse into one
        multi-row scatter — bit-equal to serving the UPs one at a time
        (``async_sim.run_batched``'s contract).  Replies are sent AFTER
        the batch commits, in schedule order.
        """
        rec = self.recorder
        slots = [self._slot_of[src] for src, _, _ in ups]
        for (src, payload, msg), slot in zip(ups, slots):
            self.up_bytes += len(payload)
            self._up_sizes.append(len(payload))
            self._count(f"client/{src}/events")
            self._count(f"client/{src}/up_bytes", len(payload))
            # per-shard counter family: scripts/report.py renders these
            # as the shard-balance table (one row per coordinator shard;
            # a mesh coordinator counts every shard's arena as served —
            # per-shard byte columns don't exist there because the mesh
            # sends ONE global frame, not S envelopes)
            if self.mesh_shards:
                for s in range(self.mesh_shards):
                    self._count(f"shard/{s}/events")
            else:
                self._count(f"shard/{self.shard_id}/events")
                self._count(f"shard/{self.shard_id}/up_bytes", len(payload))
            e = len(self._losses)
            self._losses.append(float(np.float32(msg.aux)))
            self._served_slots.append(slot)
            self._staleness.append(e - self._last_sync.get(slot, 0))
            self._last_sync[slot] = e + 1

        with rec.span("coord/server_batch", batch=len(ups)):
            ids = jnp.asarray(slots, jnp.int32)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[m.leaves[0] for _, _, m in ups])
            self.sstate, G_stack, M_rows = self._batched_server(
                self.sstate, stacked, ids)

        with rec.span("coord/encode", batch=len(ups)):
            replies, shipped = [], []
            for i, (src, payload, msg) in enumerate(ups):
                G_i = jax.tree.map(lambda x: x[i], G_stack)
                reply, ship = wire.encode_message(
                    wire.DOWN, wire.COORDINATOR_ID, msg.seq, [G_i],
                    mode=self._down_mode, seg=self._down_seg)
                replies.append(reply)
                shipped.append(ship[0])

        with rec.span("coord/commit", batch=len(ups)):
            if self._down_seg is not None:
                G_ship = jax.tree.map(lambda *xs: jnp.stack(xs), *shipped)
                self.sstate = self._commit_rows(self.sstate, ids, G_ship)
            else:
                # dense downward: v rows snap to the per-event prefix M
                self.sstate, _ = self._commit_rows(
                    self.sstate, ids, G_stack, M_rows)

        with rec.span("coord/reply", batch=len(ups)):
            for (src, payload, msg), reply in zip(ups, replies):
                self.down_bytes += len(reply)
                self._down_sizes.append(len(reply))
                self._count(f"client/{src}/down_bytes", len(reply))
                if not self.mesh_shards:
                    self._count(f"shard/{self.shard_id}/down_bytes",
                                len(reply))
                self._last_seq[src] = msg.seq
                self._reply_cache[src] = reply
                self.transport.send(src, reply)
                self._account(src, len(payload) + len(reply))

        if rec.enabled:
            rec.event("progress", event=len(self._losses),
                      batch=len(ups), loss=self._losses[-1],
                      up_bytes=self.up_bytes, down_bytes=self.down_bytes)

        if self._ckpt is not None and self.ckpt_every and \
                len(self._losses) - self._ckpt_last >= self.ckpt_every:
            with rec.span("coord/ckpt", version=len(self._losses)):
                entry = self._ckpt.append(self._live_arena(),
                                          len(self._losses))
            self._ckpt_last = len(self._losses)
            self._count("ckpt_deltas")
            self._count("ckpt_bytes", entry["nbytes"])

    def _M_flat(self):
        """The global ``(total,)`` M arena — mesh states concatenate their
        masked shard rows back (bit-equal, DESIGN.md §14)."""
        if self.mesh_shards:
            return ps.mesh_arena(self.sstate)
        return self.sstate.M

    def _live_arena(self) -> np.ndarray:
        """The served model's arena, theta_0 + M, as host f32.

        numpy and XLA:CPU run the same elementwise IEEE-754 add, so this
        equals ``space.pack(global_model(...))`` bit for bit — the
        delta-checkpoint chain restores the live model exactly.
        """
        return self._theta0_arena + np.asarray(self._M_flat(), np.float32)

    # -- serve leg ---------------------------------------------------------

    @property
    def version(self) -> int:
        """Server version: committed training events so far (host-side)."""
        return len(self._losses)

    def _quiesced(self) -> bool:
        return self._training_over or \
            (bool(self._joined) and self._joined <= self._left)

    def _subscriber_msg(self, src: int, msg):
        """Serve one subscriber frame; never touches training state.

        Every reply is a DIFF whose ``seq`` is the server version and
        whose ``aux`` flags quiescence.  Push bytes land ONLY in the
        ``sub/{i}/*`` counter family — never ``up_bytes``/``down_bytes``
        — so schedule-driven runs stay byte-identical to the simulator
        with or without a fleet attached.
        """
        sid = src - wire.SUBSCRIBER_BASE
        if msg.type == wire.SUB:
            if src not in self.book.subs:
                self.book.add(src)
                self._count("sub_joins")
            self._push(src, sid)     # the initial catch-up diff (v_sub = 0)
        elif msg.type == wire.PULL:
            if src not in self.book.subs:
                self._count("ignored")
                return
            self._push(src, sid)
        else:  # SYNC: dense full-M handshake, then the replica leaves
            if src not in self.book.subs:
                self._count("ignored")
                return
            with self.recorder.span("coord/sync", sub=sid):
                payload = self.book.sync_payload(
                    src, self._M_flat(), self.version)
                self.transport.send(src, payload)
            self._count(f"sub/{sid}/pushes")
            self._count(f"sub/{sid}/push_bytes", len(payload))
            self.counters[f"sub/{sid}/version"] = self.version
            self._count("sub_syncs")
            self.book.drop(src)

    def _push(self, src: int, sid: int):
        version = self.version
        lag = version - self.book.subs[src].version
        with self.recorder.span("coord/push", sub=sid, lag=lag):
            payload = self.book.diff_payload(
                src, self._M_flat(), version, self._quiesced())
            self.transport.send(src, payload)
        self._count(f"sub/{sid}/pushes")
        self._count(f"sub/{sid}/push_bytes", len(payload))
        self.counters[f"sub/{sid}/lag_max"] = max(
            self.counters.get(f"sub/{sid}/lag_max", 0), lag)
        self.counters[f"sub/{sid}/version"] = version

    def _poll_subscribers(self):
        """Drain pending subscriber traffic without blocking.

        Schedule-driven loops call this between turns; the transport's
        selective ``poll`` stashes (never consumes) training-client
        frames, so the served event order is untouched.
        """
        poll = getattr(self.transport, "poll", None)
        if poll is None:
            return
        while (got := poll(wire.is_subscriber)) is not None:
            src, payload = got
            try:
                msg = wire.decode_message(payload)
            except Exception:
                self._count("ignored")
                continue
            self._subscriber_msg(src, msg)

    def _drain_subscribers(self):
        """Post-training: answer PULLs with quiesced diffs until every
        subscriber (at least ``min_subscribers`` of them) has SYNCed."""
        while len(self.book.seen) < self.min_subscribers or self.book.subs:
            try:
                src, payload = self.transport.recv(
                    None, timeout=self.recv_timeout)
            except RecvTimeout:
                continue
            if wire.is_subscriber(src):
                try:
                    msg = wire.decode_message(payload)
                except Exception:
                    self._count("ignored")
                    continue
                self._subscriber_msg(src, msg)
            else:
                self._classify(src, payload)   # stray dup/bye traffic

    def _account(self, client: int, nbytes: int):
        if self.scheduler is None:
            return
        cost = 0.0
        if self.virtual_costs and client in self.virtual_costs and nbytes:
            cost = self.virtual_costs[client].frame_cost(nbytes)
            self._count(f"client/{client}/virtual_cost", cost)
        self.scheduler.account(client, cost)

    # -- the loop ----------------------------------------------------------

    def _next_turns(self, remaining: int | None) -> list[int]:
        """The scheduler's next run of turns to drain as one batch.

        ``ScheduleDriven.next_batch`` yields the maximal
        pairwise-distinct-client run (pow2-truncated); schedulers without
        it (VirtualClock — its choice depends on costs booked per event)
        serve one client at a time, as does ``max_batch=1``.
        """
        next_batch = getattr(self.scheduler, "next_batch", None)
        if next_batch is None or self.max_batch == 1:
            who = self.scheduler.next_client()
            return [] if who is None else [who]
        cap = self.max_batch
        if remaining is not None:
            cap = remaining if cap is None else min(cap, remaining)
        return next_batch(cap)

    def _collect_turn(self, who):
        """One scheduler turn: absorb control traffic from ``who``'s lane
        until it yields an UP (returned unprocessed) or ends (skip/bye)."""
        while True:
            src, payload = self.transport.recv(who, timeout=self.recv_timeout)
            kind, msg = self._classify(src, payload)
            if kind == "up":
                return src, payload, msg
            if kind in ("skip", "bye"):
                return None
            # hello/dup/ignored: keep this turn open

    def serve(self, max_events: int | None = None):
        """Run until the schedule is exhausted / every client left.

        With a scheduler, each turn serves the scheduler's chosen client
        (selective receive — arrival order cannot change the served
        order), and consecutive turns for pairwise-distinct clients drain
        through the batched server stages as ONE dispatch (bit-equal to
        serial — ``max_batch`` caps or disables this).  Without a
        scheduler (real-time TCP mode) messages are served as they come.
        """
        events = 0
        while max_events is None or events < max_events:
            if self.scheduler is not None:
                self._poll_subscribers()
                remaining = None if max_events is None else max_events - events
                turns = self._next_turns(remaining)
                if not turns:
                    break
                ups = [up for who in turns
                       if (up := self._collect_turn(who)) is not None]
                if ups:
                    self._process_ups(ups)
                    events += len(ups)
                continue
            # real-time path: one message at a time, arrival order
            try:
                src, payload = self.transport.recv(
                    None, timeout=self.recv_timeout)
            except RecvTimeout:
                if self._all_done():
                    return self._finish()
                raise
            kind, msg = self._classify(src, payload)
            if kind == "up":
                self._process_ups([(src, payload, msg)])
                events += 1
            if self._all_done():
                break
        self._training_over = True
        self._drain_subscribers()
        return self._finish()

    def _all_done(self) -> bool:
        if not (bool(self._joined) and self._joined <= self._left):
            return False
        # a serve-enabled coordinator keeps answering until the fleet has
        # arrived (min_subscribers) and every live replica has SYNCed out
        if len(self.book.seen) < self.min_subscribers:
            return False
        return not self.book.subs

    def _finish(self):
        if self._ckpt is not None:
            if self._ckpt_last < len(self._losses):
                entry = self._ckpt.append(self._live_arena(),
                                          len(self._losses))
                self._count("ckpt_deltas")
                self._count("ckpt_bytes", entry["nbytes"])
            self._ckpt.close()
        # sharded coordinators return their shard's leaves; the runner /
        # launcher concatenates shard results back into the full pytree
        final = ps.global_model(self._params0_local, self.sstate)
        if self.mesh_shards:
            # ONE host read, off the hot path: how many entries the route
            # kernel's capacity dropped (0 with the default cap — pinned
            # by the parity tests)
            self.counters["route_overflow"] = int(self.sstate.overflow)
        staleness = np.asarray(self._staleness, np.int64)
        metrics = {
            "n_events": len(self._losses),
            "per_worker": np.bincount(
                np.asarray(self._served_slots, np.int64),
                minlength=self.sstate.v.shape[0]).tolist(),
            "staleness_hist": metrics_lib.summarize_log2(staleness),
            "up_bytes_hist": metrics_lib.summarize_log2(self._up_sizes),
            "down_bytes_hist": metrics_lib.summarize_log2(self._down_sizes),
            "counters": dict(self.counters),
        }
        hist = async_sim.History(
            losses=np.asarray(self._losses, np.float64),
            worker_ids=np.asarray(self._served_slots, np.int32),
            staleness=staleness,
            up_bytes=self.up_bytes,
            down_bytes=self.down_bytes,
            evals=[],
            metrics=metrics,
        )
        rec = self.recorder
        if rec.enabled:
            for name, n in self.counters.items():
                # shard coordinators share one recorder: every shard sees
                # the same events, so only shard 0 flushes the run-level /
                # per-client families (they would multiply-count), while
                # each shard contributes its own shard/{i}/* rows
                if self.shard_id == 0 or name.startswith("shard/"):
                    rec.count(name, n)
            if self.shard_id == 0:
                async_sim._record_run_summary(
                    rec, "cluster", hist, None, None,
                    np.asarray(self._up_sizes, np.int64),
                    np.asarray(self._down_sizes, np.int64))
        return final, hist
