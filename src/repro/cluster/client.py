"""The federated client: local compute on a stale model, sparse exchange.

Runs the SAME jitted compute/apply stages as the simulator
(``async_sim.make_client_step`` / ``make_apply``); the upward message
leaves the jit raw and the wire codec quantizes it during encode, exactly
as ``AsyncTrainer`` does in-process via ``wire.quantize_message``.

Scenario behaviour lives here too: per-round participation (SKIP frames),
bounded life (BYE after ``plan.n_rounds``), and at-least-once retry — a
frame lost to fault injection is retransmitted after ``reply_timeout`` and
deduplicated by the coordinator on ``seq``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro import telemetry
from repro.core import async_sim
from repro.core.baselines import Strategy
from repro.core.paramspace import ParamSpace

from . import wire
from .scenarios import ClientPlan, participates
from .transport import RecvTimeout


@dataclasses.dataclass
class ClusterClient:
    """One worker process/thread speaking the cluster wire protocol.

    batch_fn(event_idx, slot) -> batch; ``event_fn(local_step) -> int``
    maps local steps to the event index fed to batch_fn/lr_fn — in
    schedule-driven (parity) runs this is the client's slice of the global
    schedule, otherwise the local step count.
    """

    transport: Any
    strategy: Strategy
    grad_fn: Callable
    params0: Any
    batch_fn: Callable
    plan: ClientPlan
    lr: float = 0.1
    lr_fn: Callable | None = None
    event_fn: Callable | None = None
    reply_timeout: float | None = None   # retransmit interval under drops
    max_retries: int = 50
    recorder: Any = None                 # telemetry.Recorder (None = no-op)

    def __post_init__(self):
        if self.recorder is None:
            self.recorder = telemetry.NULL
        # retransmits this client issued after a reply timed out — the
        # observable half of the fault injector's drop accounting
        self.retries = 0

    def run(self):
        """HELLO -> (UP/DOWN | SKIP)* -> BYE; returns local History-lite."""
        rec = self.recorder
        addr = self.plan.client_id
        space = ParamSpace.from_tree(self.params0)
        client_step = async_sim.make_client_step(self.strategy, self.grad_fn,
                                                 space)
        apply_G = async_sim.make_apply()
        up_mode = self.strategy.quantize
        up_seg = self.strategy.message_seg(space)

        hello, _ = wire.encode_message(wire.HELLO, addr,
                                       self._proposed_slot())
        self.transport.send(wire.COORDINATOR_ID, hello)
        _, reply = self.transport.recv(timeout=None)
        welcome = wire.decode_message(reply)
        assert welcome.type == wire.WELCOME, welcome.type
        slot = welcome.seq

        theta = space.pack(self.params0)   # the local model, as one arena
        strat = self.strategy.init(self.params0)
        losses, seq = [], 0
        for step in range(self.plan.n_rounds):
            if not participates(self.plan, step):
                skip, _ = wire.encode_message(wire.SKIP, addr, seq)
                self.transport.send(wire.COORDINATOR_ID, skip)
                continue
            e = step if self.event_fn is None else int(self.event_fn(step))
            lr = self.lr if self.lr_fn is None else float(self.lr_fn(e))
            batch = self.batch_fn(e, slot)
            with rec.span("client/step", cat=f"client/{addr}"):
                strat, loss, msg = client_step(theta, strat, batch, lr)
            with rec.span("client/encode", cat=f"client/{addr}"):
                payload, _ = wire.encode_message(
                    wire.UP, addr, seq, [msg], mode=up_mode, seg=up_seg,
                    aux=float(loss))
            with rec.span("client/exchange", cat=f"client/{addr}"):
                down = self._exchange(payload, seq)
            with rec.span("client/apply", cat=f"client/{addr}"):
                theta = apply_G(theta, down.leaves[0])
            losses.append(float(loss))
            seq += 1
        bye, _ = wire.encode_message(wire.BYE, addr, seq)
        self.transport.send(wire.COORDINATOR_ID, bye)
        return space.unpack(theta), losses

    def _proposed_slot(self) -> int:
        # schedule-driven runs pin client addr == worker slot; elastic
        # scenarios let the coordinator pick (AUTO via 0xFFFFFFFF)
        return self.plan.client_id if self.event_fn is not None \
            else 0xFFFFFFFF

    def _exchange(self, payload: bytes, seq: int) -> wire.Message:
        """Send one UP and wait for its DOWN, retransmitting on loss."""
        self.transport.send(wire.COORDINATOR_ID, payload)
        for _ in range(self.max_retries):
            try:
                _, reply = self.transport.recv(timeout=self.reply_timeout)
            except RecvTimeout:
                self.retries += 1
                self.recorder.count(
                    f"client/{self.plan.client_id}/retries")
                self.transport.send(wire.COORDINATOR_ID, payload)
                continue
            down = wire.decode_message(reply)
            if down.type == wire.DOWN and down.seq == seq:
                return down
            # stale duplicate reply from an earlier retransmit — ignore
        raise RecvTimeout(f"client {self.plan.client_id}: no reply to "
                          f"seq {seq} after {self.max_retries} retries")
