"""The federated client: local compute on a stale model, sparse exchange.

Runs the SAME jitted compute/apply stages as the simulator
(``async_sim.make_client_step`` / ``make_apply``); the upward message
leaves the jit raw and the wire codec quantizes it during encode, exactly
as ``AsyncTrainer`` does in-process via ``wire.quantize_message``.

Scenario behaviour lives here too: per-round participation (SKIP frames),
bounded life (BYE after ``plan.n_rounds``), and at-least-once retry — a
frame lost to fault injection is retransmitted after ``reply_timeout`` and
deduplicated by the coordinator on ``seq``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro import telemetry
from repro.core import async_sim
from repro.core.baselines import Strategy
from repro.core.paramspace import ParamSpace

from . import wire
from .scenarios import ClientPlan, participates
from .transport import RecvTimeout


@dataclasses.dataclass
class ClusterClient:
    """One worker process/thread speaking the cluster wire protocol.

    batch_fn(event_idx, slot) -> batch; ``event_fn(local_step) -> int``
    maps local steps to the event index fed to batch_fn/lr_fn — in
    schedule-driven (parity) runs this is the client's slice of the global
    schedule, otherwise the local step count.

    Against a SHARDED parameter server (DESIGN.md §12) ``transport`` is a
    list of per-shard transports (shard order) and ``shard_spec`` the
    range partition: each upward message splits by index range and fans
    out as one shard-local frame per coordinator shard, and the per-shard
    downward diffs merge (indices rebased back by ``bounds[s]``) into one
    global message before the single arena apply — bit-equal to the
    unsharded exchange.
    """

    transport: Any                       # one transport, or one per shard
    strategy: Strategy
    grad_fn: Callable
    params0: Any
    batch_fn: Callable
    plan: ClientPlan
    lr: float = 0.1
    lr_fn: Callable | None = None
    event_fn: Callable | None = None
    reply_timeout: float | None = None   # retransmit interval under drops
    max_retries: int = 50
    recorder: Any = None                 # telemetry.Recorder (None = no-op)
    shard_spec: Any = None               # ShardSpec; required with S > 1
    pin_slot: bool = False               # propose slot == client_id on HELLO

    def __post_init__(self):
        if self.recorder is None:
            self.recorder = telemetry.NULL
        self._transports = (list(self.transport)
                            if isinstance(self.transport, (list, tuple))
                            else [self.transport])
        if len(self._transports) > 1 and self.shard_spec is None:
            raise ValueError("a sharded client (multiple transports) "
                             "needs shard_spec=")
        if self.shard_spec is not None \
                and len(self._transports) != self.shard_spec.n_shards:
            raise ValueError(
                f"{len(self._transports)} transports for "
                f"{self.shard_spec.n_shards} shards")
        # retransmits this client issued after a reply timed out — the
        # observable half of the fault injector's drop accounting
        self.retries = 0

    def run(self):
        """HELLO -> (UP/DOWN | SKIP)* -> BYE; returns local History-lite."""
        rec = self.recorder
        addr = self.plan.client_id
        space = ParamSpace.from_tree(self.params0)
        client_step = async_sim.make_client_step(self.strategy, self.grad_fn,
                                                 space)
        apply_G = async_sim.make_apply()
        up_mode = self.strategy.quantize
        up_seg = self.strategy.message_seg(space)

        hello, _ = wire.encode_message(wire.HELLO, addr,
                                       self._proposed_slot())
        slot = None
        for tp in self._transports:
            tp.send(wire.COORDINATOR_ID, hello)
            _, reply = tp.recv(timeout=None)
            welcome = wire.decode_message(reply)
            assert welcome.type == wire.WELCOME, welcome.type
            # every shard must seat this client in the same v-row slot so
            # batch_fn(e, slot) is well defined — shard 0 decides
            if slot is None:
                slot = welcome.seq

        theta = space.pack(self.params0)   # the local model, as one arena
        strat = self.strategy.init(self.params0)
        losses, seq = [], 0
        for step in range(self.plan.n_rounds):
            if not participates(self.plan, step):
                skip, _ = wire.encode_message(wire.SKIP, addr, seq)
                for tp in self._transports:
                    tp.send(wire.COORDINATOR_ID, skip)
                continue
            e = step if self.event_fn is None else int(self.event_fn(step))
            lr = self.lr if self.lr_fn is None else float(self.lr_fn(e))
            batch = self.batch_fn(e, slot)
            with rec.span("client/step", cat=f"client/{addr}"):
                strat, loss, msg = client_step(theta, strat, batch, lr)
            with rec.span("client/encode", cat=f"client/{addr}"):
                if self.shard_spec is not None:
                    frames = wire.encode_sharded_message(
                        wire.UP, addr, seq, msg, shard_spec=self.shard_spec,
                        mode=up_mode, seg=up_seg, aux=float(loss))
                    payloads = [p for p, _ in frames]
                else:
                    payload, _ = wire.encode_message(
                        wire.UP, addr, seq, [msg], mode=up_mode, seg=up_seg,
                        aux=float(loss))
                    payloads = [payload]
            with rec.span("client/exchange", cat=f"client/{addr}"):
                # fan out every shard's UP before blocking on any DOWN:
                # the shards run concurrently, the client pays one RTT
                for tp, p in zip(self._transports, payloads):
                    tp.send(wire.COORDINATOR_ID, p)
                downs = [self._await_down(tp, p, seq)
                         for tp, p in zip(self._transports, payloads)]
            with rec.span("client/apply", cat=f"client/{addr}"):
                if self.shard_spec is not None:
                    G = self.shard_spec.merge([d.leaves[0] for d in downs])
                else:
                    G = downs[0].leaves[0]
                theta = apply_G(theta, G)
            losses.append(float(loss))
            seq += 1
        bye, _ = wire.encode_message(wire.BYE, addr, seq)
        for tp in self._transports:
            tp.send(wire.COORDINATOR_ID, bye)
        return space.unpack(theta), losses

    def _proposed_slot(self) -> int:
        # schedule-driven runs pin client addr == worker slot; elastic
        # scenarios let the coordinator pick (AUTO via 0xFFFFFFFF).
        # pin_slot forces pinning for sharded runs, where every shard
        # coordinator must agree on the slot (see run()).
        if self.event_fn is not None or self.pin_slot:
            return self.plan.client_id
        return 0xFFFFFFFF

    def _await_down(self, transport, payload: bytes,
                    seq: int) -> wire.Message:
        """Wait for one shard's DOWN to ``seq``, retransmitting on loss.

        The UP was already sent by the fan-out loop in :meth:`run`; this
        only retransmits after a timeout (at-least-once, deduplicated by
        the coordinator on ``seq``).
        """
        for _ in range(self.max_retries):
            try:
                _, reply = transport.recv(timeout=self.reply_timeout)
            except RecvTimeout:
                self.retries += 1
                self.recorder.count(
                    f"client/{self.plan.client_id}/retries")
                transport.send(wire.COORDINATOR_ID, payload)
                continue
            down = wire.decode_message(reply)
            if down.type == wire.DOWN and down.seq == seq:
                return down
            # stale duplicate reply from an earlier retransmit — ignore
        raise RecvTimeout(f"client {self.plan.client_id}: no reply to "
                          f"seq {seq} after {self.max_retries} retries")
