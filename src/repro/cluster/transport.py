"""Transports for the federated cluster runtime.

A transport moves opaque byte payloads (wire.py messages) between numbered
endpoints.  Two backends share one interface:

* :class:`InProcHub` — thread-safe queues inside one process.  Fully
  deterministic when the coordinator drives receives with ``recv(src=...)``
  (selective receive): arrival *order* across clients never influences the
  served order, so a schedule-driven coordinator reproduces the simulator's
  event sequence exactly no matter how client threads interleave.
* :class:`TcpCoordinatorTransport` / :class:`TcpClientTransport` — real
  length-prefixed frames over TCP sockets, one process per peer.

Event *schedulers* decide which client the coordinator serves next:

* :class:`ScheduleDriven` — an explicit worker-slot order (e.g. from
  ``async_sim.make_schedule``); the bit-parity mode.
* :class:`VirtualClock` — per-client virtual completion times advanced by
  compute time + measured message bytes / bandwidth + fault delay; the
  generalization of ``make_schedule`` that knows about bandwidth caps,
  joins, and leaves.

Fault injection (:class:`FaultPolicy` + :class:`FaultInjector`) applies
per-client bandwidth caps, extra latency, and seeded frame drops at the
transport boundary.  Dropped frames are survived by the client's
send-with-retry loop and the coordinator's duplicate-``seq`` cache
(coordinator.py) — classic at-least-once delivery.
"""
from __future__ import annotations

import dataclasses
import queue
import socket
import struct
import threading
import time
from typing import Protocol, runtime_checkable

import numpy as np

_FRAME_LEN = struct.Struct("<I")
_ANNOUNCE = struct.Struct("<I")


class TransportClosed(ConnectionError):
    pass


class RecvTimeout(TimeoutError):
    pass


@runtime_checkable
class Transport(Protocol):
    """Point-to-point byte transport between numbered endpoints."""

    def send(self, dst: int, payload: bytes) -> None: ...

    def recv(self, src: int | None = None, *,
             timeout: float | None = None) -> tuple[int, bytes]: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# shared inbox with selective receive
# ---------------------------------------------------------------------------

class _Inbox:
    """One merged queue + per-source stash so ``recv(src=k)`` is possible
    regardless of the order other peers' messages arrive in."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue()
        self._stash: dict[int, list[bytes]] = {}

    def put(self, src: int, payload: bytes):
        self._q.put((src, payload))

    def get(self, src: int | None, timeout: float | None):
        if src is None:
            for s, items in self._stash.items():
                if items:
                    return s, items.pop(0)
            try:
                return self._q.get(timeout=timeout)
            except queue.Empty:
                raise RecvTimeout("no message")
        items = self._stash.get(src)
        if items:
            return src, items.pop(0)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            try:
                s, payload = self._q.get(timeout=remaining)
            except queue.Empty:
                raise RecvTimeout(f"no message from {src}")
            if s == src:
                return s, payload
            self._stash.setdefault(s, []).append(payload)

    def poll(self, accept):
        """Non-blocking selective drain: the next message whose *source*
        satisfies ``accept(src)``, or None.

        Messages from non-accepted sources are stashed — exactly what a
        selective :meth:`get` would do with them — so polling for (e.g.)
        subscriber-range traffic never reorders or consumes the frames a
        schedule-driven receive loop is waiting on.
        """
        for s, items in self._stash.items():
            if items and accept(s):
                return s, items.pop(0)
        while True:
            try:
                s, payload = self._q.get_nowait()
            except queue.Empty:
                return None
            if accept(s):
                return s, payload
            self._stash.setdefault(s, []).append(payload)


# ---------------------------------------------------------------------------
# in-process backend
# ---------------------------------------------------------------------------

class InProcHub:
    """Registry of in-process endpoints addressed by integer id."""

    def __init__(self):
        self._inboxes: dict[int, _Inbox] = {}
        self._lock = threading.Lock()

    def endpoint(self, addr: int) -> "InProcEndpoint":
        with self._lock:
            if addr in self._inboxes:
                raise ValueError(f"address {addr} already registered")
            self._inboxes[addr] = _Inbox()
        return InProcEndpoint(self, addr)

    def _deliver(self, src: int, dst: int, payload: bytes):
        try:
            inbox = self._inboxes[dst]
        except KeyError:
            raise TransportClosed(f"no endpoint {dst}")
        inbox.put(src, payload)


@dataclasses.dataclass
class InProcEndpoint:
    hub: InProcHub
    addr: int

    def send(self, dst: int, payload: bytes) -> None:
        self.hub._deliver(self.addr, dst, payload)

    def recv(self, src: int | None = None, *,
             timeout: float | None = None) -> tuple[int, bytes]:
        return self.hub._inboxes[self.addr].get(src, timeout)

    def poll(self, accept):
        return self.hub._inboxes[self.addr].poll(accept)

    def close(self) -> None:
        pass


class ShardEndpointView:
    """One client endpoint seen through a single coordinator SHARD.

    The sharded parameter server (DESIGN.md §12) runs ``S`` coordinator
    shards on distinct addresses; a client keeps ONE inbox but speaks to
    every shard.  This view pins sends addressed to the logical
    coordinator onto shard ``shard_addr`` and receives selectively from
    it (the shared inbox stashes other shards' replies), so the client's
    per-shard exchange loop reuses the unsharded protocol verbatim.
    """

    def __init__(self, endpoint, shard_addr: int):
        self.endpoint = endpoint
        self.shard_addr = shard_addr

    def send(self, dst: int, payload: bytes) -> None:
        self.endpoint.send(self.shard_addr, payload)

    def recv(self, src: int | None = None, *,
             timeout: float | None = None) -> tuple[int, bytes]:
        return self.endpoint.recv(self.shard_addr, timeout=timeout)

    def close(self) -> None:
        pass   # the shared endpoint outlives its shard views


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Per-client link model: cap, latency, loss.

    bandwidth: bytes/second (None = infinite); delay: extra seconds per
    frame; drop_prob: probability a frame is silently lost; seed makes the
    drop sequence reproducible.  ``realtime=False`` (in-process virtual-time
    runs) books the cost with the scheduler instead of sleeping.
    """

    bandwidth: float | None = None
    delay: float = 0.0
    drop_prob: float = 0.0
    seed: int = 0
    realtime: bool = True

    def frame_cost(self, nbytes: int) -> float:
        cost = self.delay
        if self.bandwidth:
            cost += nbytes / self.bandwidth
        return cost


class FaultInjector:
    """Wrap an endpoint with a FaultPolicy (applies to sends only).

    ``droppable(payload) -> bool`` restricts loss to frames the sender will
    retransmit (the runtime passes UP frames only — losing a fire-and-forget
    SKIP/BYE would strand the coordinator waiting on a turn that never
    comes); bandwidth/delay costs still apply to every frame.
    """

    def __init__(self, inner, policy: FaultPolicy, droppable=None):
        self.inner = inner
        self.policy = policy
        self.droppable = droppable or (lambda payload: True)
        self._rng = np.random.default_rng(policy.seed)
        self.dropped = 0

    def send(self, dst: int, payload: bytes) -> None:
        if self.policy.realtime:
            cost = self.policy.frame_cost(len(payload))
            if cost:
                time.sleep(cost)
        # realtime=False: byte costs are booked by the coordinator against
        # its VirtualClock (Coordinator._account), not here
        if self.policy.drop_prob and self.droppable(payload) and \
                self._rng.random() < self.policy.drop_prob:
            self.dropped += 1
            return
        self.inner.send(dst, payload)

    def recv(self, src: int | None = None, *, timeout: float | None = None):
        return self.inner.recv(src, timeout=timeout)

    def close(self) -> None:
        self.inner.close()


# ---------------------------------------------------------------------------
# event schedulers
# ---------------------------------------------------------------------------

class ScheduleDriven:
    """Serve clients in an explicit slot order (bit-parity with the
    simulator's ``make_schedule``)."""

    def __init__(self, order):
        self.order = [int(x) for x in order]
        self._i = 0

    def register(self, client: int, t_join: float = 0.0):
        pass

    def next_client(self) -> int | None:
        if self._i >= len(self.order):
            return None
        k = self.order[self._i]
        self._i += 1
        return k

    def next_batch(self, max_batch: int | None = None) -> list[int]:
        """The next maximal run of PAIRWISE-DISTINCT clients, truncated to
        a power of two — ``async_sim.batch_schedule``'s rule, so a batched
        coordinator serves the exact event order the simulator batches.
        Advances the cursor by the kept length; empty when exhausted."""
        n = len(self.order)
        if self._i >= n:
            return []
        limit = n if max_batch is None else min(n, self._i + int(max_batch))
        seen: set[int] = set()
        j = self._i
        while j < limit and self.order[j] not in seen:
            seen.add(self.order[j])
            j += 1
        size = 1 << ((j - self._i).bit_length() - 1)
        batch = self.order[self._i:self._i + size]
        self._i += size
        return batch

    def account(self, client: int, cost: float):
        pass

    def deactivate(self, client: int):
        pass


class VirtualClock:
    """Argmin-of-completion-times scheduler (bandwidth/fault aware).

    The continuous-time generalization of ``async_sim.make_schedule``:
    each client k has a virtual clock t_k; the next served client is the
    active one with the smallest t_k, and serving advances t_k by its
    compute time plus whatever byte/fault costs the coordinator books via
    :meth:`account`.
    """

    def __init__(self, compute_time=None):
        self._t: dict[int, float] = {}
        self._dt: dict[int, float] = {}
        self._active: set[int] = set()
        self._compute_time = compute_time or {}

    def register(self, client: int, t_join: float = 0.0,
                 compute_time: float = 1.0):
        self._t[client] = t_join
        self._dt[client] = self._compute_time.get(client, compute_time)
        self._active.add(client)

    def next_client(self) -> int | None:
        if not self._active:
            return None
        return min(self._active, key=lambda k: (self._t[k], k))

    def account(self, client: int, cost: float = 0.0):
        self._t[client] += self._dt[client] + cost

    def deactivate(self, client: int):
        self._active.discard(client)

    @property
    def now(self) -> float:
        return min((self._t[k] for k in self._active), default=0.0)


# ---------------------------------------------------------------------------
# TCP backend
# ---------------------------------------------------------------------------

def _read_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportClosed("peer closed")
        buf += chunk
    return buf


def _write_frame(sock: socket.socket, payload: bytes):
    sock.sendall(_FRAME_LEN.pack(len(payload)) + payload)


def _read_frame(sock: socket.socket) -> bytes:
    (n,) = _FRAME_LEN.unpack(_read_exact(sock, _FRAME_LEN.size))
    return _read_exact(sock, n)


class TcpCoordinatorTransport:
    """Listening side: accepts clients, one reader thread per connection.

    Each client announces its integer address right after connecting; all
    subsequent frames land in the shared inbox tagged with it.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._listener = socket.create_server((host, port))
        self.host, self.port = self._listener.getsockname()[:2]
        self._inbox = _Inbox()
        self._conns: dict[int, socket.socket] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    def _accept_loop(self):
        while not self._closed:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            threading.Thread(target=self._reader, args=(conn,),
                             daemon=True).start()

    def _reader(self, conn: socket.socket):
        try:
            (addr,) = _ANNOUNCE.unpack(_read_exact(conn, _ANNOUNCE.size))
            with self._lock:
                self._conns[addr] = conn
            while True:
                self._inbox.put(addr, _read_frame(conn))
        except (TransportClosed, OSError):
            conn.close()

    def send(self, dst: int, payload: bytes) -> None:
        with self._lock:
            conn = self._conns.get(dst)
        if conn is None:
            raise TransportClosed(f"client {dst} not connected")
        _write_frame(conn, payload)

    def recv(self, src: int | None = None, *,
             timeout: float | None = None) -> tuple[int, bytes]:
        return self._inbox.get(src, timeout)

    def poll(self, accept):
        return self._inbox.poll(accept)

    def close(self) -> None:
        self._closed = True
        self._listener.close()
        with self._lock:
            for conn in self._conns.values():
                conn.close()
            self._conns.clear()


class TcpClientTransport:
    """Connecting side: one socket to the coordinator.

    Receives through a persistent buffer so a ``recv`` timeout that fires
    mid-frame never loses the partial bytes — the retry loop's next call
    resumes the same frame instead of desyncing the stream.
    """

    def __init__(self, host: str, port: int, addr: int,
                 connect_timeout: float = 30.0):
        from repro.cluster import wire

        self.addr = addr
        self._coord = wire.COORDINATOR_ID
        self._buf = b""
        deadline = time.monotonic() + connect_timeout
        while True:
            try:
                self._sock = socket.create_connection(
                    (host, port), timeout=connect_timeout)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.05)   # coordinator may still be binding
        self._sock.sendall(_ANNOUNCE.pack(addr))

    def send(self, dst: int, payload: bytes) -> None:
        _write_frame(self._sock, payload)

    def recv(self, src: int | None = None, *,
             timeout: float | None = None) -> tuple[int, bytes]:
        self._sock.settimeout(timeout)
        try:
            while True:
                if len(self._buf) >= _FRAME_LEN.size:
                    (n,) = _FRAME_LEN.unpack_from(self._buf, 0)
                    end = _FRAME_LEN.size + n
                    if len(self._buf) >= end:
                        payload = self._buf[_FRAME_LEN.size:end]
                        self._buf = self._buf[end:]
                        return self._coord, payload
                chunk = self._sock.recv(1 << 16)
                if not chunk:
                    raise TransportClosed("coordinator closed")
                self._buf += chunk
        except socket.timeout:
            raise RecvTimeout("coordinator silent")

    def close(self) -> None:
        self._sock.close()
