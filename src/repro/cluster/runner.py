"""Assemble a whole cluster in one process (threads over InProcHub).

Two entry points:

* :func:`run_inprocess` with ``schedule=...`` — the bit-parity mode: the
  coordinator serves clients in exactly the given ``make_schedule`` order
  (client address == worker slot), reproducing ``AsyncTrainer.run`` losses
  bit-for-bit while every byte still crosses the real codec.
* :func:`run_inprocess` with ``plans=...`` — the scenario mode: a
  :class:`transport.VirtualClock` orders events by per-client virtual time
  (compute speed + measured bytes / bandwidth + fault delay), supporting
  partial participation, joins/leaves, and non-IID sharding.

``n_shards > 1`` range-partitions the parameter arena across S coordinator
shards (DESIGN.md §12): each shard runs its OWN copy of the schedule over
its own endpoint, clients fan every up-frame out by index range and merge
the per-shard downward diffs — losses/params reproduce the single-shard
run bit-for-bit because disjoint-range scatter-adds commute.

``mesh_shards = S`` runs the same range partition as ONE coordinator
hosting all S shard arenas in-graph (DESIGN.md §14): the stacked mesh
server stages route every message through the alltoallv exchange, clients
see a single ordinary endpoint, and both losses/params AND up/down bytes
reproduce the single-server run bit-for-bit (the S-thread runtime's bytes
differ — S wire envelopes per event).  Mutually exclusive with
``n_shards > 1``; works with plans/fault injection like any single
coordinator.
"""
from __future__ import annotations

import threading

import jax
import numpy as np

from repro.core import engine as engine_lib
from repro.core.engine import CompressionSpec
from repro.core.paramspace import ParamSpace, ShardSpec

from . import wire
from .client import ClusterClient
from .coordinator import Coordinator
from .scenarios import ClientPlan
from .transport import (FaultInjector, InProcHub, ScheduleDriven,
                        ShardEndpointView, VirtualClock)


def run_inprocess(
    strategy,
    grad_fn,
    params0,
    batch_fn,
    *,
    n_workers: int | None = None,
    schedule=None,
    plans: list[ClientPlan] | None = None,
    lr: float = 0.1,
    lr_fn=None,
    secondary_density: float | None = None,
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC,
    inject_faults: bool = False,
    timeout: float = 300.0,
    recorder=None,
    n_shards: int = 1,
    mesh_shards: int = 0,
    n_replicas: int = 0,
    push_density: float | None = None,
    push_spec: CompressionSpec = engine_lib.EXACT_SPEC,
    max_staleness: int = 4,
    replica_decode_fn=None,
    ckpt_dir=None,
    ckpt_every: int = 0,
):
    """Run coordinator + clients on the in-process transport.

    Exactly one of ``schedule`` (parity mode) / ``plans`` (scenario mode)
    must be given.  Returns ``(final_params, History)`` like
    ``AsyncTrainer.run`` minus the server state.

    ``n_replicas > 0`` attaches a live inference fleet (DESIGN.md §13):
    each replica subscribes, pulls re-sparsified model-diffs between
    decode boundaries, and SYNCs to the bit-exact final model at quiesce.
    Replica results land in ``History.metrics["replicas"]`` (per-replica
    stats + final arena); training losses/bytes are untouched — serving
    reads M only.
    """
    if (schedule is None) == (plans is None):
        raise ValueError("pass exactly one of schedule= or plans=")
    if mesh_shards and n_shards > 1:
        raise ValueError(
            "n_shards and mesh_shards are two different sharding runtimes "
            "(S coordinator threads vs one in-graph mesh stage) — pass "
            "exactly one of them")
    if n_replicas and n_shards > 1:
        raise NotImplementedError(
            "the serve leg subscribes to ONE coordinator arena; sharded "
            "serving needs per-shard subscriptions (future work)")
    if n_replicas and mesh_shards:
        raise NotImplementedError(
            "mesh-sharded serving is a later PR: the subscriber book's "
            "cursor diffs read a flat M arena, and re-sparsified pushes "
            "from the stacked mesh state are untested — run replicas "
            "against an unsharded (or S-thread sharded) coordinator")
    if n_shards > 1:
        if plans is not None:
            raise NotImplementedError(
                "sharded runs are schedule-driven (parity mode); the "
                "VirtualClock scenario scheduler books per-client costs "
                "event by event, which S independent shard clocks cannot "
                "reproduce consistently")
        if inject_faults:
            raise NotImplementedError(
                "fault injection wraps a client's single endpoint; the "
                "sharded client multiplexes one endpoint across shard "
                "views — inject faults on single-shard runs")

    hub = InProcHub()
    coord_t = hub.endpoint(wire.COORDINATOR_ID)

    if schedule is not None:
        schedule = np.asarray(schedule)
        n_workers = int(n_workers or (schedule.max() + 1))
        events_of = {k: np.flatnonzero(schedule == k)
                     for k in range(n_workers)}
        # a worker with no scheduled events would block on WELCOME forever
        plans = [ClientPlan(client_id=k, n_rounds=len(events_of[k]))
                 for k in range(n_workers) if len(events_of[k])]
        scheduler = ScheduleDriven(schedule)
        max_events = len(schedule)
        virtual_costs = None
    else:
        n_workers = n_workers or len(plans)
        events_of = None
        scheduler = VirtualClock()
        for p in plans:
            scheduler.register(p.client_id, t_join=p.join_time,
                               compute_time=p.compute_time)
        max_events = None
        virtual_costs = {p.client_id: p.fault_policy(realtime=False)
                         for p in plans}

    shard_spec = None
    if n_shards > 1:
        shard_spec = ShardSpec.for_space(ParamSpace.from_tree(params0),
                                         n_shards)

    coord = Coordinator(
        transport=coord_t,
        params0=params0,
        n_slots=n_workers,
        secondary_density=secondary_density,
        secondary_spec=secondary_spec,
        scheduler=scheduler,
        virtual_costs=virtual_costs,
        recv_timeout=timeout,
        recorder=recorder,
        shard_spec=shard_spec,
        shard_id=0,
        mesh_shards=mesh_shards,
        push_density=push_density,
        push_spec=push_spec,
        min_subscribers=n_replicas,
        ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every,
    )
    # shards 1..S-1: same schedule, own cursor, own endpoint — every shard
    # sees the identical event stream (clients fan each UP out to all of
    # them), so the independent ScheduleDriven copies stay in lockstep
    shard_coords = [Coordinator(
        transport=hub.endpoint(wire.COORDINATOR_ID - s),
        params0=params0,
        n_slots=n_workers,
        secondary_density=secondary_density,
        secondary_spec=secondary_spec,
        scheduler=ScheduleDriven(schedule),
        recv_timeout=timeout,
        recorder=recorder,
        shard_spec=shard_spec,
        shard_id=s,
    ) for s in range(1, n_shards)]

    clients, threads, errors, injectors = [], [], [], {}
    for p in plans:
        endpoint = hub.endpoint(p.client_id)
        if inject_faults:
            endpoint = FaultInjector(
                endpoint, p.fault_policy(realtime=False),
                droppable=lambda payload: payload[:1] == bytes([wire.UP]))
            injectors[p.client_id] = endpoint
        c = ClusterClient(
            transport=(endpoint if n_shards == 1 else
                       [ShardEndpointView(endpoint, wire.COORDINATOR_ID - s)
                        for s in range(n_shards)]),
            shard_spec=shard_spec,
            strategy=strategy,
            grad_fn=grad_fn,
            params0=params0,
            batch_fn=batch_fn,
            plan=p,
            lr=lr,
            lr_fn=lr_fn,
            event_fn=(
                (lambda step, ev=events_of[p.client_id]: ev[step])
                if events_of is not None else None),
            reply_timeout=1.0 if inject_faults else None,
            recorder=recorder,
        )
        clients.append(c)

        def _run(c=c):
            try:
                c.run()
            except Exception as exc:  # surface client failures in the test
                errors.append(exc)

        t = threading.Thread(target=_run, daemon=True)
        threads.append(t)
        t.start()

    replicas, replica_results = [], [None] * n_replicas
    replica_threads = []
    for i in range(n_replicas):
        from .replica import InferenceReplica
        r = InferenceReplica(
            hub.endpoint(wire.SUBSCRIBER_BASE + i), params0,
            replica_id=i, max_staleness=max_staleness,
            decode_fn=replica_decode_fn, recorder=recorder,
            recv_timeout=timeout)
        replicas.append(r)

        def _serve_replica(i=i, r=r):
            try:
                replica_results[i] = r.run()
            except Exception as exc:
                errors.append(exc)

        t = threading.Thread(target=_serve_replica, daemon=True)
        replica_threads.append(t)
        t.start()

    shard_results: list = [None] * n_shards
    coord_errors: list = []

    def _serve_shard(s, c):
        try:
            shard_results[s] = c.serve(max_events=max_events)
        except Exception as exc:
            coord_errors.append(exc)

    shard_threads = [threading.Thread(target=_serve_shard, args=(s + 1, c),
                                      daemon=True)
                     for s, c in enumerate(shard_coords)]
    for t in shard_threads:
        t.start()
    try:
        final, hist = coord.serve(max_events=max_events)
    except Exception:
        if errors:   # a dead client explains the coordinator timeout better
            raise errors[0]
        if coord_errors:
            raise coord_errors[0]
        raise
    for t in threads:
        t.join(timeout=timeout)
    for t in shard_threads:
        t.join(timeout=timeout)
    for t in replica_threads:
        t.join(timeout=timeout)
    if errors:
        raise errors[0]
    if coord_errors:
        raise coord_errors[0]
    if n_shards > 1:
        # stitch the shard results back together: shard 0's History carries
        # the event log (every shard saw the identical stream), bytes sum
        # across shards, shard/{i}/* counters merge, and the per-shard leaf
        # lists concatenate back into the full parameter pytree (shard
        # order == leaf order for a leaf-aligned ShardSpec)
        shard_results[0] = (final, hist)
        leaves = [leaf for f, _ in shard_results
                  for leaf in jax.tree.leaves(f)]
        final = jax.tree.unflatten(jax.tree.structure(params0), leaves)
        counters = dict(hist.metrics["counters"])
        for _, h in shard_results[1:]:
            counters.update({k: v for k, v in h.metrics["counters"].items()
                             if k.startswith("shard/")})
        hist = hist._replace(
            up_bytes=sum(h.up_bytes for _, h in shard_results),
            down_bytes=sum(h.down_bytes for _, h in shard_results),
            metrics={**hist.metrics, "counters": counters})
    # fold the clients' fault accounting into the coordinator's metrics:
    # injected drops (from each FaultInjector) vs observed retransmits
    # (from each client) — what test_cluster's accounting test reconciles
    if hist.metrics is not None:
        per_client = {c.plan.client_id: {
            "retries": c.retries,
            "drops": getattr(injectors.get(c.plan.client_id), "dropped", 0),
        } for c in clients}
        hist = hist._replace(
            metrics={**hist.metrics, "clients": per_client})
    if n_replicas and hist.metrics is not None:
        hist = hist._replace(metrics={**hist.metrics, "replicas": [
            None if r is None else
            {"arena": r.arena, "version": r.version, **r.stats}
            for r in replica_results]})
    return final, hist
