"""Assemble a whole cluster in one process (threads over InProcHub).

Two entry points:

* :func:`run_inprocess` with ``schedule=...`` — the bit-parity mode: the
  coordinator serves clients in exactly the given ``make_schedule`` order
  (client address == worker slot), reproducing ``AsyncTrainer.run`` losses
  bit-for-bit while every byte still crosses the real codec.
* :func:`run_inprocess` with ``plans=...`` — the scenario mode: a
  :class:`transport.VirtualClock` orders events by per-client virtual time
  (compute speed + measured bytes / bandwidth + fault delay), supporting
  partial participation, joins/leaves, and non-IID sharding.
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import engine as engine_lib
from repro.core.engine import CompressionSpec

from . import wire
from .client import ClusterClient
from .coordinator import Coordinator
from .scenarios import ClientPlan
from .transport import FaultInjector, InProcHub, ScheduleDriven, VirtualClock


def run_inprocess(
    strategy,
    grad_fn,
    params0,
    batch_fn,
    *,
    n_workers: int | None = None,
    schedule=None,
    plans: list[ClientPlan] | None = None,
    lr: float = 0.1,
    lr_fn=None,
    secondary_density: float | None = None,
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC,
    inject_faults: bool = False,
    timeout: float = 300.0,
    recorder=None,
):
    """Run coordinator + clients on the in-process transport.

    Exactly one of ``schedule`` (parity mode) / ``plans`` (scenario mode)
    must be given.  Returns ``(final_params, History)`` like
    ``AsyncTrainer.run`` minus the server state.
    """
    if (schedule is None) == (plans is None):
        raise ValueError("pass exactly one of schedule= or plans=")

    hub = InProcHub()
    coord_t = hub.endpoint(wire.COORDINATOR_ID)

    if schedule is not None:
        schedule = np.asarray(schedule)
        n_workers = int(n_workers or (schedule.max() + 1))
        events_of = {k: np.flatnonzero(schedule == k)
                     for k in range(n_workers)}
        # a worker with no scheduled events would block on WELCOME forever
        plans = [ClientPlan(client_id=k, n_rounds=len(events_of[k]))
                 for k in range(n_workers) if len(events_of[k])]
        scheduler = ScheduleDriven(schedule)
        max_events = len(schedule)
        virtual_costs = None
    else:
        n_workers = n_workers or len(plans)
        events_of = None
        scheduler = VirtualClock()
        for p in plans:
            scheduler.register(p.client_id, t_join=p.join_time,
                               compute_time=p.compute_time)
        max_events = None
        virtual_costs = {p.client_id: p.fault_policy(realtime=False)
                         for p in plans}

    coord = Coordinator(
        transport=coord_t,
        params0=params0,
        n_slots=n_workers,
        secondary_density=secondary_density,
        secondary_spec=secondary_spec,
        scheduler=scheduler,
        virtual_costs=virtual_costs,
        recv_timeout=timeout,
        recorder=recorder,
    )

    clients, threads, errors, injectors = [], [], [], {}
    for p in plans:
        endpoint = hub.endpoint(p.client_id)
        if inject_faults:
            endpoint = FaultInjector(
                endpoint, p.fault_policy(realtime=False),
                droppable=lambda payload: payload[:1] == bytes([wire.UP]))
            injectors[p.client_id] = endpoint
        c = ClusterClient(
            transport=endpoint,
            strategy=strategy,
            grad_fn=grad_fn,
            params0=params0,
            batch_fn=batch_fn,
            plan=p,
            lr=lr,
            lr_fn=lr_fn,
            event_fn=(
                (lambda step, ev=events_of[p.client_id]: ev[step])
                if events_of is not None else None),
            reply_timeout=1.0 if inject_faults else None,
            recorder=recorder,
        )
        clients.append(c)

        def _run(c=c):
            try:
                c.run()
            except Exception as exc:  # surface client failures in the test
                errors.append(exc)

        t = threading.Thread(target=_run, daemon=True)
        threads.append(t)
        t.start()

    try:
        final, hist = coord.serve(max_events=max_events)
    except Exception:
        if errors:   # a dead client explains the coordinator timeout better
            raise errors[0]
        raise
    for t in threads:
        t.join(timeout=timeout)
    if errors:
        raise errors[0]
    # fold the clients' fault accounting into the coordinator's metrics:
    # injected drops (from each FaultInjector) vs observed retransmits
    # (from each client) — what test_cluster's accounting test reconciles
    if hist.metrics is not None:
        per_client = {c.plan.client_id: {
            "retries": c.retries,
            "drops": getattr(injectors.get(c.plan.client_id), "dropped", 0),
        } for c in clients}
        hist = hist._replace(
            metrics={**hist.metrics, "clients": per_client})
    return final, hist
