"""The inference replica: decode against a live, sparsely-updated model.

A replica SUBscribes to the training coordinator and interleaves decode
work with DIFF pulls (DESIGN.md §13):

    SUB  -> DIFF(residual)          initial catch-up: all of M so far
    PULL -> DIFF(residual)          one coalesced re-sparsified push
    ...                             decode, decode, ...
    SYNC -> DIFF(M, dense)          bit-exact final handshake

Pulls are *pipelined* against decode: the replica fires a PULL, keeps
decoding, and opportunistically applies the reply at the next batch
boundary.  The staleness bound caps the pipeline — after
``max_staleness`` decode boundaries with the PULL still unanswered, the
replica blocks until the diff lands (bounded-staleness serving, the
client-side mirror of the coordinator's per-push version-lag counters).

Diff apply is Eq. 5 — ``theta <- theta + G`` through the same fused
scatter (``kernels.ops.scatter_add``) as the training client; the final
model is ``theta_0 + M`` computed as one dense elementwise add, bit-equal
to ``server.global_model``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import server as ps
from repro.core.paramspace import ParamSpace

from . import wire
from .transport import RecvTimeout

# TcpClientTransport maps ``settimeout(0)`` to non-blocking mode (raising
# BlockingIOError, not socket.timeout) — poll with a small epsilon instead
POLL_EPS = 0.01


@dataclasses.dataclass
class ReplicaResult:
    arena: np.ndarray     # final (total,) f32 — theta_0 + M, bit-exact
    params: Any           # the same, unpacked to the parameter pytree
    version: int          # server version at SYNC
    stats: dict


class InferenceReplica:
    """One subscriber endpoint: pull sparse diffs, decode, SYNC out."""

    def __init__(self, transport, params0, *, replica_id: int = 0,
                 max_staleness: int = 4,
                 decode_fn: Callable | None = None,
                 recorder=None, recv_timeout: float | None = None):
        self.transport = transport
        self.replica_id = int(replica_id)
        self.addr = wire.SUBSCRIBER_BASE + self.replica_id
        self.max_staleness = max(1, int(max_staleness))
        self.decode_fn = decode_fn
        self.recorder = telemetry.NULL if recorder is None else recorder
        self.recv_timeout = recv_timeout
        self.space = ParamSpace.from_tree(params0)
        # host-side theta_0 arena: the SYNC handshake recomputes
        # theta_0 + M from it, and theta starts from a FRESH device buffer
        # (apply donates its input — theta_0's buffer must survive)
        self._theta0 = np.asarray(self.space.pack(params0), np.float32)
        self.stats = {"pulls": 0, "diffs": 0, "decodes": 0, "bytes_in": 0,
                      "applied_entries": 0, "stale_waits": 0,
                      "version_jump_max": 0}
        self.version = -1

    # -- protocol ----------------------------------------------------------

    def _recv_diff(self, timeout):
        _, payload = self.transport.recv(None, timeout=timeout)
        msg = wire.decode_message(payload)
        if msg.type != wire.DIFF:
            raise ValueError(f"replica expected DIFF, got "
                             f"{wire.TYPE_NAMES.get(msg.type, msg.type)}")
        self.stats["bytes_in"] += len(payload)
        return msg

    def _apply(self, theta, msg):
        leaf = msg.leaves[0]
        with self.recorder.span("replica/apply", replica=self.replica_id,
                                version=msg.seq):
            theta = ps.apply_update(theta, leaf)
        self.stats["diffs"] += 1
        self.stats["applied_entries"] += int(getattr(leaf, "k", 0))
        if self.version >= 0:
            self.stats["version_jump_max"] = max(
                self.stats["version_jump_max"], int(msg.seq) - self.version)
        self.version = int(msg.seq)
        return theta, float(msg.aux) >= 1.0

    def run(self, max_decodes: int | None = None) -> ReplicaResult:
        """Decode until training quiesces (or ``max_decodes``), then SYNC.

        Returns the bit-exact final model; ``decode_fn(params, step)`` is
        called at every decode boundary with the replica's CURRENT
        (bounded-staleness) parameters.
        """
        rec = self.recorder
        theta = jnp.asarray(self._theta0)
        payload, _ = wire.encode_message(wire.SUB, self.addr, 0)
        self.transport.send(wire.COORDINATOR_ID, payload)
        theta, quiesced = self._apply(
            theta, self._recv_diff(self.recv_timeout))

        pending = False   # one in-flight PULL at a time
        stale = 0
        step = 0
        while not quiesced and (max_decodes is None or step < max_decodes):
            if not pending:
                payload, _ = wire.encode_message(wire.PULL, self.addr, step)
                self.transport.send(wire.COORDINATOR_ID, payload)
                self.stats["pulls"] += 1
                pending, stale = True, 0
            else:
                try:
                    block = stale >= self.max_staleness
                    if block:
                        self.stats["stale_waits"] += 1
                    msg = self._recv_diff(
                        self.recv_timeout if block else POLL_EPS)
                    theta, quiesced = self._apply(theta, msg)
                    pending = False
                except RecvTimeout:
                    stale += 1
            if self.decode_fn is not None:
                with rec.span("replica/decode", replica=self.replica_id,
                              step=step):
                    self.decode_fn(self.space.unpack(theta), step)
            self.stats["decodes"] += 1
            step += 1

        if pending:   # absorb the outstanding reply before the handshake
            theta, quiesced = self._apply(
                theta, self._recv_diff(self.recv_timeout))

        # SYNC: the coordinator answers with ALL of M, dense; theta_0 + M
        # is the same elementwise f32 add as server.global_model, so the
        # served model matches the trainer's final bits exactly
        payload, _ = wire.encode_message(wire.SYNC, self.addr, step)
        self.transport.send(wire.COORDINATOR_ID, payload)
        msg = self._recv_diff(self.recv_timeout)
        from repro.core.sparsify import SparseLeaf
        if isinstance(msg.leaves[0], SparseLeaf):
            raise ValueError("SYNC reply must be a dense arena frame")
        with rec.span("replica/sync", replica=self.replica_id,
                      version=msg.seq):
            arena = self._theta0 + np.asarray(msg.leaves[0], np.float32)
        self.version = int(msg.seq)
        self.stats["version"] = self.version
        if rec.enabled:
            for k, v in self.stats.items():
                rec.count(f"replica/{self.replica_id}/{k}", v)
        return ReplicaResult(arena=arena,
                             params=self.space.unpack(jnp.asarray(arena)),
                             version=self.version, stats=dict(self.stats))
