"""Federated scenario knobs the single-process simulator cannot express.

* :class:`ClientPlan` — one client's life: when it joins (virtual time),
  how many rounds it runs, its compute speed, link model (bandwidth cap /
  latency / loss), and its per-round participation probability.
* :func:`participates` — seeded, per-(client, round) participation draw:
  partial participation / client sampling without any coordination.
* :func:`dirichlet_class_weights` + :class:`NonIIDClassification` —
  label-skewed (non-IID) data sharding: each client draws labels from its
  own Dirichlet(alpha) class distribution over the shared gaussian-blobs
  task, the standard federated heterogeneity benchmark.
* :func:`hetero_plans` — a fleet builder mirroring ``make_schedule``'s
  lognormal speed model, with optional stragglers, late joiners, and early
  leavers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import ClassificationTask

from .transport import FaultPolicy


@dataclasses.dataclass(frozen=True)
class ClientPlan:
    """Everything scenario-specific about one client."""

    client_id: int
    n_rounds: int = 10
    join_time: float = 0.0        # virtual time the client becomes active
    compute_time: float = 1.0     # virtual seconds per local step
    participation: float = 1.0    # per-round participation probability
    bandwidth: float | None = None  # uplink bytes/second (None = infinite)
    delay: float = 0.0            # extra seconds per frame
    drop_prob: float = 0.0        # uplink frame loss probability
    seed: int = 0

    def fault_policy(self, *, realtime: bool = True) -> FaultPolicy:
        return FaultPolicy(bandwidth=self.bandwidth, delay=self.delay,
                           drop_prob=self.drop_prob,
                           seed=(self.seed * 9973 + self.client_id),
                           realtime=realtime)


def participates(plan: ClientPlan, round_idx: int) -> bool:
    """Seeded per-round participation draw — identical on every replay."""
    if plan.participation >= 1.0:
        return True
    rng = np.random.default_rng(
        (plan.seed, plan.client_id, round_idx))
    return bool(rng.random() < plan.participation)


def hetero_plans(
    n_clients: int,
    n_rounds: int,
    *,
    hetero: float = 0.5,
    seed: int = 0,
    participation: float = 1.0,
    late_join: int = 0,
    early_leave: int = 0,
    bandwidth: float | None = None,
    drop_prob: float = 0.0,
) -> list[ClientPlan]:
    """A heterogeneous fleet: lognormal compute speeds (same model as
    ``async_sim.make_schedule``), the last ``late_join`` clients joining
    mid-run and the first ``early_leave`` leaving after half their rounds."""
    rng = np.random.default_rng(seed)
    speeds = np.exp(rng.normal(0.0, hetero, n_clients))
    plans = []
    for c in range(n_clients):
        joins_late = c >= n_clients - late_join
        leaves_early = c < early_leave
        plans.append(ClientPlan(
            client_id=c,
            n_rounds=max(1, n_rounds // 2) if leaves_early else n_rounds,
            join_time=float(n_rounds / 2) if joins_late else 0.0,
            compute_time=float(1.0 / speeds[c]),
            participation=participation,
            bandwidth=bandwidth,
            drop_prob=drop_prob,
            seed=seed,
        ))
    return plans


# ---------------------------------------------------------------------------
# non-IID data sharding
# ---------------------------------------------------------------------------

def dirichlet_class_weights(
    n_clients: int, n_classes: int, alpha: float, *, seed: int = 0,
) -> np.ndarray:
    """(n_clients, n_classes) row-stochastic label distributions.

    Small ``alpha`` concentrates each client on few classes (strong skew);
    ``alpha -> inf`` recovers the IID uniform distribution.
    """
    rng = np.random.default_rng(seed)
    w = rng.dirichlet(np.full(n_classes, alpha), size=n_clients)
    return w.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class NonIIDClassification:
    """Label-skewed view of :class:`ClassificationTask`.

    Same gaussian-blob geometry and eval set as the IID task — only each
    client's label marginal changes, so accuracy numbers stay comparable.
    """

    task: ClassificationTask
    alpha: float = 0.3
    shard_seed: int = 0
    n_clients: int = 8

    def weights(self) -> np.ndarray:
        # per-instance memo (not lru_cache: that would pin every instance
        # in a module-global cache for the interpreter's lifetime);
        # read-only so a caller can't corrupt later batch() draws
        w = self.__dict__.get("_weights")
        if w is None:
            w = dirichlet_class_weights(self.n_clients, self.task.n_classes,
                                        self.alpha, seed=self.shard_seed)
            w.setflags(write=False)
            object.__setattr__(self, "_weights", w)
        return w

    def _weights_dev(self, client: int):
        cache = self.__dict__.get("_weights_dev_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_weights_dev_cache", cache)
        if client not in cache:
            cache[client] = jnp.asarray(self.weights()[client])
        return cache[client]

    def batch(self, step: int, client: int):
        w = self._weights_dev(client)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.task.seed), step),
            client)
        ky, kx = jax.random.split(key)
        y = jax.random.choice(ky, self.task.n_classes,
                              (self.task.batch_size,), p=w)
        x = self.task.centers()[y] + self.task.noise * jax.random.normal(
            kx, (self.task.batch_size, self.task.n_features))
        return x, y

    def eval_set(self, n: int = 512):
        return self.task.eval_set(n)
