"""Subscriber-side state of the serve leg: residual arenas + DIFF framing.

An inference replica is a *read-only worker* (DESIGN.md §13): the
coordinator keeps a cursor arena ``v_sub`` per subscriber — exactly the
per-worker ``v_k`` row of the parameter server (Eq. 3/4) — and every push
ships the re-sparsified residual

    r = M - v_sub

as ONE coalesced ARENA frame.  Committing the *shipped* leaf back into
``v_sub`` (the same fused scatter as the training commit) makes the
residual self-correcting: whatever top-k selection or wire quantization
dropped this push stays in ``M - v_sub`` and rides the next one — DGC-style
accumulation of everything the subscriber hasn't seen, so a slow replica
gets one catch-up diff, never a replay.

The final handshake is bit-exact by construction: SYNC answers with the
FULL accumulated update ``M`` as a dense frame, and the replica computes
``theta = theta_0 + M`` — the same elementwise f32 add as
``server.global_model`` — so replica parameters match the server's final
model bit for bit regardless of what the sparse pushes dropped.

This module owns the per-subscriber state and framing math only; the
coordinator drives transport, counters, and spans.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_lib
from repro.core.engine import CompressionSpec
from repro.core.sparsify import SparseLeaf

from . import wire


@dataclasses.dataclass
class Subscriber:
    """One replica's cursor state on the coordinator."""

    addr: int
    v: object            # (total,) f32 cursor arena — what it has seen
    version: int = 0     # server version its last DIFF brought it to
    pushes: int = 0
    push_bytes: int = 0
    lag_max: int = 0
    synced: bool = False


class SubscriberBook:
    """Cursor arenas + DIFF/SYNC framing for every live subscriber."""

    def __init__(self, space, *, push_density: float | None = None,
                 push_spec: CompressionSpec = engine_lib.EXACT_SPEC):
        self.space = space
        self.push_density = push_density
        self.push_spec = push_spec
        self._select_spec = dataclasses.replace(push_spec, quantize="none")
        self._ks = (space.ks(push_density)
                    if push_density is not None else None)
        self.subs: dict[int, Subscriber] = {}
        self.seen: set[int] = set()

    def live(self) -> list[int]:
        return sorted(self.subs)

    def add(self, addr: int) -> Subscriber:
        """Register ``addr`` with a zero cursor (residual = all of M, so
        its first DIFF is the full catch-up — same rule as a fresh worker
        slot)."""
        sub = Subscriber(addr=addr,
                         v=jnp.zeros((self.space.total,), jnp.float32))
        self.subs[addr] = sub
        self.seen.add(addr)
        return sub

    def drop(self, addr: int):
        self.subs.pop(addr, None)

    # -- framing -----------------------------------------------------------

    def _residual_leaf(self, sub: Subscriber):
        """Re-sparsified residual of everything ``sub`` hasn't seen.

        ``push_density`` set: per-tensor top-|.| of ``M - v_sub`` through
        the engine registry (static shapes, one jitted program — the
        training path's own selection).  ``None``: the exact nonzero
        residual, host-side (dynamic k; serving is off the jit hot path).
        """
        r = self._M - sub.v
        if self._ks is not None:
            return self.space.select(r, self._ks, self._select_spec), self._ks
        r_np = np.asarray(r)
        idx = np.flatnonzero(r_np)
        leaf = SparseLeaf(values=jnp.asarray(r_np[idx]),
                          indices=jnp.asarray(idx.astype(np.int32)),
                          size=self.space.total)
        return leaf, (int(idx.size),) if idx.size else ()

    def diff_payload(self, addr: int, M, version: int,
                     quiesced: bool) -> bytes:
        """One push: encode the residual DIFF and commit the shipped bits.

        ``seq`` carries the server version this diff brings the replica
        to; ``aux`` is 1.0 once training quiesced (the replica's cue to
        SYNC).  The SHIPPED leaf — what the decoder reconstructs after
        wire quantization — is scatter-added into ``v_sub``, so the
        cursor tracks exactly the bits the replica applied.
        """
        sub = self.subs[addr]
        self._M = M
        leaf, seg = self._residual_leaf(sub)
        payload, shipped = wire.encode_message(
            wire.DIFF, wire.COORDINATOR_ID, version & 0xFFFFFFFF, [leaf],
            mode=self.push_spec.quantize, seg=seg,
            aux=1.0 if quiesced else 0.0)
        ship = shipped[0]
        if ship.k:
            from repro.kernels import ops
            sub.v = ops.scatter_add(sub.v, ship.indices, ship.values)
        sub.lag_max = max(sub.lag_max, version - sub.version)
        sub.version = version
        sub.pushes += 1
        sub.push_bytes += len(payload)
        return payload

    def sync_payload(self, addr: int, M, version: int) -> bytes:
        """The bit-exact final: the full accumulated update, dense.

        The replica reconstructs ``theta_0 + M`` — identical bits to
        ``server.global_model`` (same elementwise f32 add) — so no sparse
        push history can leave residue in the served model.
        """
        sub = self.subs[addr]
        payload, _ = wire.encode_message(
            wire.DIFF, wire.COORDINATOR_ID, version & 0xFFFFFFFF,
            [np.asarray(M, np.float32)], aux=1.0)
        sub.lag_max = max(sub.lag_max, version - sub.version)
        sub.version = version
        sub.pushes += 1
        sub.push_bytes += len(payload)
        sub.synced = True
        return payload
