"""Packed binary wire codec for the federated cluster runtime.

Every message between a client and the coordinator is one *envelope*
followed by zero or more length-prefixed *frames*:

    envelope:   u8  type      HELLO/WELCOME/UP/DOWN/SKIP/BYE
                u32 sender    client id (coordinator = 0xFFFFFFFF)
                u32 seq       per-sender sequence number (control types
                              reuse this field: HELLO = proposed id,
                              WELCOME = assigned worker slot)
                f32 aux       UP: the worker's scalar loss; else 0
                u32 n_leaves

    frame:      u32 frame_len (bytes after this field)
                u16 leaf_id   (ARENA frames reuse this field as n_seg)
                u8  mode      value packing: 0 none / 1 bf16 / 2 int8 / 3 tern
                u8  kind      0 sparse COO / 1 dense f32 / 2 dense-as-COO /
                              3 ARENA (global-index COO over the packed
                              parameter arena, segmented per tensor)
                u32 k         number of entries carried
                u32 size      dense length of the leaf / arena
                [f32 scale]   kind 0, int8/tern only: the per-message scale
                uN * k        indices (kinds 0, 2, 3); N derived from
                              ``size`` — u8 when size <= 256, u16 when
                              size <= 65536, u32 beyond — so the decoder
                              needs no extra field
                values        none: f32*k | bf16: u16*k | int8: i8*k
                              tern: 2-bit codes, 4 per byte
                              dense f32 (kind 1): f32*size, no indices

    ARENA body (kind 3) carries, between the header and the index block:
                u32 * n_seg   per-tensor entry counts (the segmentation)
                f32 * n_seg   int8/tern only: one scale PER TENSOR

The arena frame is how the flat-parameter-arena runtime (DESIGN.md §8)
ships a whole model's sparse update as ONE frame: one header, one index
block whose width derives from the arena ``size``, one value block.
Quantization is segment-wise — one scale per original tensor, exactly like
the old per-leaf frames — so arena messages are bit-equal to per-leaf ones
and the decoder never needs the model structure (it reads the seg table).

All integers little-endian.  Dense leaves always travel as f32 (quantizing
the model-difference would break the server's ``v_k == M`` invariant, Eq. 4);
the codec picks whichever of kind 1/2 is smaller for the actual nnz.

Quantization semantics are *exactly* ``sparsify.quantize_dequantize``:
``decode(encode(values, mode))`` reproduces ``quantize_dequantize(values,
mode)[0]`` bit-for-bit (tests/test_wire.py).  The same jitted quantizer is
exposed as :func:`quantize_message` and used by ``core.async_sim`` so the
simulator's arithmetic — and therefore its losses — is bit-identical to a
cluster run over this codec.

:func:`frame_bytes` computes the serialized size of a message from its
structure alone; it is definitionally equal to ``len(encode_message(...))``
and replaces the old analytic byte accounting everywhere.
"""
from __future__ import annotations

import struct
from typing import NamedTuple

import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.sparsify import (SparseLeaf, quantize_parts as
                                 _quantize_parts, quantize_segments)

# message types
HELLO, WELCOME, UP, DOWN, SKIP, BYE = range(6)
# subscriber leg (DESIGN.md §13): an inference replica SUBscribes to the
# coordinator, PULLs one coalesced re-sparsified model-diff per decode
# boundary, and SYNCs (full accumulated update, dense) for the bit-exact
# final handshake.  Every subscriber-bound reply is a DIFF frame whose
# ``seq`` field carries the server version (committed event count) the
# diff brings the replica to, and whose ``aux`` field is 1.0 once
# training has quiesced (the replica's cue to SYNC and leave).
SUB, PULL, SYNC, DIFF = 6, 7, 8, 9
TYPE_NAMES = {HELLO: "HELLO", WELCOME: "WELCOME", UP: "UP", DOWN: "DOWN",
              SKIP: "SKIP", BYE: "BYE", SUB: "SUB", PULL: "PULL",
              SYNC: "SYNC", DIFF: "DIFF"}
COORDINATOR_ID = 0xFFFFFFFF

# inference replicas address themselves from a reserved id range so the
# coordinator can recognize (and selectively drain) subscriber traffic
# without disturbing the schedule-driven selective receive of training
# clients — client ids are small ints, shard coordinators sit just under
# COORDINATOR_ID, and 2**30 collides with neither.
SUBSCRIBER_BASE = 1 << 30


def is_subscriber(addr: int) -> bool:
    """True when ``addr`` is in the reserved inference-replica id range."""
    return SUBSCRIBER_BASE <= addr < COORDINATOR_ID - (1 << 16)

# value packing modes (wire codes)
MODES = {"none": 0, "bf16": 1, "int8": 2, "tern": 3}
MODE_NAMES = {v: k for k, v in MODES.items()}

# leaf kinds
SPARSE, DENSE, DENSE_COO, ARENA = 0, 1, 2, 3

_ENVELOPE = struct.Struct("<BIIfI")     # 17 bytes
_LEN = struct.Struct("<I")              # 4-byte leaf frame length prefix
_HEADER = struct.Struct("<HBBII")       # 12-byte leaf header
_SCALE = struct.Struct("<f")

ENVELOPE_BYTES = _ENVELOPE.size


class Message(NamedTuple):
    type: int
    sender: int
    seq: int
    aux: float
    leaves: list  # [SparseLeaf | flat f32 jax array], leaf_id order


# ---------------------------------------------------------------------------
# quantization — sparsify.quantize_parts is the single implementation; the
# codec ships its (codes, scale) and async_sim applies its dequantized
# values, so both sides of the parity contract share one XLA program
# ---------------------------------------------------------------------------

def quantize_message(msg, mode: str, seg=None):
    """Apply wire quantization to a message — what the decoder on the far
    side will reconstruct; async_sim and the scan runner call it in place
    of a real encode/decode round trip (it is pure jax, so it also runs
    in-graph inside ``lax.scan``).

    ``msg`` is one arena leaf (global-index SparseLeaf or dense flat
    array); ``seg`` gives the per-tensor segmentation of a sparse arena
    message — each segment quantizes with its own scale, matching the
    ARENA frame encoder bit-for-bit (defaults to one segment).  Dense
    leaves pass through untouched (they travel f32, see module doc).
    A legacy list of per-leaf messages quantizes leaf-wise.
    """
    if isinstance(msg, (list, tuple)) and not isinstance(msg, SparseLeaf):
        return [quantize_message(m, mode) for m in msg]
    if mode == "none" or not isinstance(msg, SparseLeaf):
        return msg
    if seg is None:
        seg = (msg.k,)
    return SparseLeaf(values=quantize_segments(msg.values, mode, seg),
                      indices=msg.indices, size=msg.size)


# ---------------------------------------------------------------------------
# size accounting — matches serialization by construction
# ---------------------------------------------------------------------------

def _value_nbytes(k: int, mode: str) -> int:
    return {"none": 4 * k, "bf16": 2 * k, "int8": k,
            "tern": (k + 3) // 4}[mode]


def index_dtype(size: int):
    """Narrowest unsigned index type for a ``size``-element leaf — derived
    from the header's ``size`` field, so it costs no wire bytes."""
    if size <= 1 << 8:
        return np.uint8
    if size <= 1 << 16:
        return np.uint16
    return np.uint32


def _index_nbytes(size: int) -> int:
    return np.dtype(index_dtype(size)).itemsize


def leaf_frame_bytes(k: int, size: int, mode: str, kind: int = SPARSE) -> int:
    """Serialized bytes of one leaf frame, length prefix included."""
    n = _LEN.size + _HEADER.size
    if kind == DENSE:
        return n + 4 * size
    if kind == DENSE_COO:
        return n + (4 + _index_nbytes(size)) * k
    if mode in ("int8", "tern"):
        n += _SCALE.size
    return n + _index_nbytes(size) * k + _value_nbytes(k, mode)


def arena_frame_bytes(seg, size: int, mode: str = "none") -> int:
    """Serialized bytes of one ARENA frame (length prefix included) — a
    pure function of the static ``(seg, size, mode)`` triple."""
    k = sum(seg)
    n = _LEN.size + _HEADER.size + 4 * len(seg)     # header + seg table
    if mode in ("int8", "tern"):
        n += 4 * len(seg)                           # one scale per tensor
    return n + _index_nbytes(size) * k + _value_nbytes(k, mode)


def frame_bytes_static(seg, size: int, mode: str = "none") -> int:
    """Per-event wire bytes of a sparse arena message (envelope included).

    Static per ``(mode, seg, size)`` — memoize once per run instead of
    re-deriving frame sizes from on-device message structure every event.
    """
    return _ENVELOPE.size + arena_frame_bytes(seg, size, mode)


def shard_frame_bytes_static(shard_spec, seg, mode: str = "none"):
    """Per-shard static wire bytes of one sharded sparse arena message.

    Shard ``s`` ships its own ARENA frame over the ``sizes[s]``-element
    sub-arena: its slice of the seg table, indices rebased shard-local
    (and therefore possibly NARROWER — ``index_dtype`` derives from the
    shard size, not the global arena size), its tensors' scales.  The
    tuple is a pure function of ``(shard_spec, seg, mode)``; its sum is
    the sharded run's exact per-event up/down byte cost (each shard pays
    its own envelope + header — the only bytes an S-shard run adds over
    the single-server frame).
    """
    return tuple(
        frame_bytes_static(shard_spec.shard_seg(seg, s), size, mode)
        for s, size in enumerate(shard_spec.sizes))


def encode_sharded_message(msg_type: int, sender: int, seq: int, msg, *,
                           shard_spec, mode: str = "none", seg=None,
                           aux: float = 0.0):
    """Route one arena message as ``S`` shard-local frames (DESIGN.md §12).

    The message splits by index range (``ShardSpec.split_by_shard`` —
    indices rebased ``global - bounds[s]``, seg table sliced per shard)
    and each piece encodes as its own complete message so coordinator
    shard ``s`` decodes ONLY its range, with per-tensor quantization
    scales identical to the unsharded frame (leaf-aligned shards keep
    whole tensors, so each segment's scale is computed over the same
    values).  Returns ``[(payload, shipped_piece), ...]`` in shard order;
    ``ShardSpec.merge`` of the shipped pieces is bit-equal to the
    single-frame ``encode_message`` shipped leaf.
    """
    out = []
    for piece, sub_seg in shard_spec.split_by_shard(msg, seg):
        out.append(encode_message(msg_type, sender, seq, [piece],
                                  mode=mode, seg=sub_seg, aux=aux))
    return out


def dense_frame_bytes(nnz, size: int):
    """Frame bytes of a dense f32 leaf with ``nnz`` nonzeros — the codec
    picks the cheaper of DENSE / DENSE_COO.  Works elementwise on numpy
    arrays of nnz (the scan runner's vectorized accounting)."""
    coo = (4 + _index_nbytes(size)) * nnz
    body = np.where(coo < 4 * size, coo, 4 * size)
    return _LEN.size + _HEADER.size + body


def _dense_kind(nnz: int, size: int) -> int:
    """COO when (idx, value) pairs beat the dense f32 vector."""
    return (DENSE_COO
            if (4 + _index_nbytes(size)) * nnz < 4 * size else DENSE)


def frame_bytes(msgs, *, mode: str = "none", seg=None,
                envelope: bool = True) -> int:
    """Wire size of a message — equal to ``len(encode_message(...))``.

    Accepts one arena leaf or a legacy list of per-leaf messages.  ``seg``
    marks a SparseLeaf as an ARENA frame with that segmentation; without
    it the legacy per-leaf SPARSE framing is counted.  Headers, per-tensor
    scales, and the bit-packed value widths are all counted exactly as
    serialized.
    """
    if isinstance(msgs, SparseLeaf) or not isinstance(msgs, (list, tuple)):
        msgs = [msgs]
    total = _ENVELOPE.size if envelope else 0
    for m in msgs:
        if isinstance(m, SparseLeaf):
            if seg is not None:
                total += arena_frame_bytes(seg, int(m.size), mode)
            else:
                total += leaf_frame_bytes(m.k, m.size, mode, SPARSE)
        else:
            # count on-device: only the scalar nnz crosses to the host
            total += int(dense_frame_bytes(int(jnp.count_nonzero(m)),
                                           int(m.size)))
    return total


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

def _pack_tern(codes: np.ndarray) -> bytes:
    """{-1, 0, +1} int8 -> 2-bit codes (two's complement), 4 per byte."""
    u = (codes.astype(np.int8) & 3).astype(np.uint8)
    pad = (-len(u)) % 4
    if pad:
        u = np.concatenate([u, np.zeros(pad, np.uint8)])
    u = u.reshape(-1, 4)
    return (u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4)
            | (u[:, 3] << 6)).astype(np.uint8).tobytes()


def _unpack_tern(buf: bytes, k: int) -> np.ndarray:
    b = np.frombuffer(buf, np.uint8)
    u = np.empty((len(b), 4), np.uint8)
    for j in range(4):
        u[:, j] = (b >> (2 * j)) & 3
    codes = u.reshape(-1)[:k].astype(np.int8)
    codes[codes == 3] = -1
    return codes


def _pack_values(codes, mode: str) -> bytes:
    if mode == "none":
        return np.asarray(codes, np.float32).tobytes()
    if mode == "bf16":
        return np.asarray(codes).view(np.uint16).tobytes()
    if mode == "int8":
        return np.asarray(codes).tobytes()
    return _pack_tern(np.asarray(codes))  # tern


def encode_arena_leaf_segments(leaf: SparseLeaf, mode: str, seg):
    """Reference ARENA encoder: the original python-side segment loop.

    One jitted ``quantize_parts`` dispatch plus two host transfers PER
    SEGMENT — kept as the semantics oracle :func:`pack_from_arena` is
    tested bit-equal against (tests/test_wire.py), and as the simplest
    statement of the frame layout.  Returns ``(frame_bytes, shipped)``.
    """
    seg = tuple(int(s) for s in seg)
    k, size = int(leaf.k), int(leaf.size)
    assert sum(seg) == k, (seg, k)
    if not seg:   # an empty shard's frame: header only (k == 0)
        body = _HEADER.pack(0, MODES[mode], ARENA, 0, size)
        return _LEN.pack(len(body)) + body, leaf
    idx = np.asarray(leaf.indices).astype(index_dtype(size))
    codes, scales, dq = [], [], []
    off = 0
    for s in seg:
        c, sc, d = _quantize_parts(leaf.values[off:off + s], mode)
        codes.append(np.asarray(c))
        scales.append(float(sc))
        dq.append(d)
        off += s
    body = _HEADER.pack(len(seg), MODES[mode], ARENA, k, size)
    body += np.asarray(seg, np.uint32).tobytes()
    if mode in ("int8", "tern"):
        body += np.asarray(scales, np.float32).tobytes()
    body += idx.tobytes() + _pack_values(np.concatenate(codes), mode)
    # the dequantized segments ARE quantize_segments(values, mode, seg) —
    # same jitted program per slice — so `shipped` costs no second pass
    shipped = SparseLeaf(
        values=dq[0] if len(dq) == 1 else jnp.concatenate(dq),
        indices=leaf.indices, size=size)
    return _LEN.pack(len(body)) + body, shipped


def pack_from_arena(leaf: SparseLeaf, mode: str, seg):
    """Fused zero-copy ARENA encode (kernels/wire_pack.py).

    ONE jitted program quantizes every segment with its own scale and
    emits the bit-packed wire value block, the per-tensor scale vector,
    and the dequantized shipped values; a second tiny program narrows the
    indices on device.  The leaf's values/indices can be views straight
    off the flat parameter arena (nothing copies before the program
    runs), and exactly three buffers cross to the host per message —
    codes, scales, indices — instead of two per segment.  Bit-equal to
    :func:`encode_arena_leaf_segments`, byte for byte.  On TPU the value
    packing runs as Pallas kernels; elsewhere as the identical XLA ops.
    Returns ``(frame_bytes, shipped_leaf)``.
    """
    from repro.kernels import wire_pack
    seg = tuple(int(s) for s in seg)
    k, size = int(leaf.k), int(leaf.size)
    assert sum(seg) == k, (seg, k)
    if not seg:   # an empty shard's frame: header only (k == 0)
        body = _HEADER.pack(0, MODES[mode], ARENA, 0, size)
        return _LEN.pack(len(body)) + body, leaf
    codes, scales, dq = wire_pack.quantize_pack(
        leaf.values, mode=mode, seg=seg)
    idx = wire_pack.narrow_indices(leaf.indices, size=size)
    body = _HEADER.pack(len(seg), MODES[mode], ARENA, k, size)
    body += np.asarray(seg, np.uint32).tobytes()
    if mode in ("int8", "tern"):
        body += np.asarray(scales).tobytes()
    body += np.asarray(idx).tobytes() + np.asarray(codes).tobytes()
    shipped = SparseLeaf(values=dq, indices=leaf.indices, size=size)
    return _LEN.pack(len(body)) + body, shipped


def encode_arena_leaf(leaf: SparseLeaf, mode: str, seg):
    """Serialize one global-index arena message as an ARENA frame.

    ``seg`` is the static per-tensor entry count tuple (sum == leaf.k).
    Each segment's values quantize with their OWN scale through the same
    quantization arithmetic as ``quantize_message`` — so ``shipped``
    (what the decoder reconstructs) is bit-equal to the in-process
    stand-in.  Routed through the fused :func:`pack_from_arena` path.
    Returns ``(frame_bytes, shipped_leaf)``.
    """
    return pack_from_arena(leaf, mode, seg)


def encode_leaf(leaf_id: int, leaf, mode: str = "none", seg=None):
    """Serialize one leaf; returns ``(frame_bytes, shipped_leaf)``.

    ``shipped_leaf`` is exactly what :func:`decode_leaf` on the far side
    reconstructs (the dequantized SparseLeaf, or the dense array verbatim)
    — callers use it to keep local state consistent with the receiver.
    A SparseLeaf with ``seg`` travels as a segmented ARENA frame; without
    it, as a legacy per-leaf SPARSE frame.
    """
    if isinstance(leaf, SparseLeaf) and seg is not None:
        return encode_arena_leaf(leaf, mode, seg)
    if isinstance(leaf, SparseLeaf):
        codes, scale, dq = _quantize_parts(leaf.values, mode)
        k, size = leaf.k, leaf.size
        idx = np.asarray(leaf.indices).astype(index_dtype(size))
        if mode == "none":
            vals = np.asarray(codes, np.float32).tobytes()
        elif mode == "bf16":
            vals = np.asarray(codes).view(np.uint16).tobytes()
        elif mode == "int8":
            vals = np.asarray(codes).tobytes()
        else:  # tern
            vals = _pack_tern(np.asarray(codes))
        body = _HEADER.pack(leaf_id, MODES[mode], SPARSE, k, size)
        if mode in ("int8", "tern"):
            body += _SCALE.pack(float(scale))
        body += idx.tobytes() + vals
        shipped = SparseLeaf(values=dq, indices=leaf.indices, size=size)
        return _LEN.pack(len(body)) + body, shipped

    flat = np.asarray(leaf, np.float32).reshape(-1)
    nz = np.flatnonzero(flat)
    kind = _dense_kind(len(nz), flat.size)
    if kind == DENSE:
        body = _HEADER.pack(leaf_id, MODES["none"], DENSE,
                            flat.size, flat.size) + flat.tobytes()
    else:
        body = (_HEADER.pack(leaf_id, MODES["none"], DENSE_COO,
                             len(nz), flat.size)
                + nz.astype(index_dtype(flat.size)).tobytes()
                + flat[nz].tobytes())
    return _LEN.pack(len(body)) + body, leaf


def encode_message(msg_type: int, sender: int, seq: int, msgs=(),
                   *, mode: str = "none", seg=None, aux: float = 0.0):
    """Serialize a full message; returns ``(payload, shipped_msgs)``.

    ``msgs`` is the leaf list (the arena runtime ships exactly one leaf:
    the global-index arena message); ``seg`` routes SparseLeaf leaves
    through the segmented ARENA framing.
    """
    if isinstance(msgs, SparseLeaf) or not isinstance(msgs, (list, tuple)):
        msgs = [msgs]
    if seg is not None and sum(isinstance(m, SparseLeaf) for m in msgs) > 1:
        # the ARENA header reuses the leaf_id field as n_seg, so an arena
        # frame cannot carry a leaf id — a message holds at most ONE
        # (decode would collapse several onto leaves[0])
        raise ValueError("arena (seg=) messages carry exactly one "
                         f"SparseLeaf; got {len(msgs)} leaves")
    frames, shipped = [], []
    for i, m in enumerate(msgs):
        frame, s = encode_leaf(i, m, mode, seg)
        frames.append(frame)
        shipped.append(s)
    payload = _ENVELOPE.pack(msg_type, sender, seq, aux, len(frames))
    return payload + b"".join(frames), shipped


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_leaf(buf, offset: int = 0):
    """Decode one leaf frame; returns ``(leaf_id, leaf, next_offset)``."""
    (blen,) = _LEN.unpack_from(buf, offset)
    offset += _LEN.size
    end = offset + blen
    leaf_id, mode_c, kind, k, size = _HEADER.unpack_from(buf, offset)
    offset += _HEADER.size
    mode = MODE_NAMES[mode_c]

    idt = index_dtype(size)
    if kind == ARENA:
        n_seg = leaf_id  # ARENA frames reuse the leaf_id field as n_seg
        seg = np.frombuffer(buf, np.uint32, n_seg, offset)
        offset += seg.nbytes
        scales = None
        if mode in ("int8", "tern"):
            scales = np.frombuffer(buf, np.float32, n_seg, offset)
            offset += scales.nbytes
        idx = np.frombuffer(buf, idt, k, offset).astype(np.int32)
        offset += k * np.dtype(idt).itemsize
        if mode == "none":
            vals = np.frombuffer(buf, np.float32, k, offset).copy()
        elif mode == "bf16":
            vals = np.frombuffer(buf, np.uint16, k, offset) \
                .view(ml_dtypes.bfloat16).astype(np.float32)
        else:
            if mode == "int8":
                codes = np.frombuffer(buf, np.int8, k, offset)
            else:  # tern
                codes = _unpack_tern(bytes(buf[offset:end]), k)
            vals = np.empty(k, np.float32)
            off = 0
            for s, sc in zip(seg, scales):
                # same IEEE op per segment as the jitted `codes * scale`
                vals[off:off + s] = codes[off:off + s].astype(np.float32) \
                    * sc
                off += s
        return 0, SparseLeaf(values=jnp.asarray(vals),
                             indices=jnp.asarray(idx), size=size), end
    if kind == DENSE:
        flat = np.frombuffer(buf, np.float32, size, offset).copy()
        return leaf_id, jnp.asarray(flat), end
    if kind == DENSE_COO:
        idx = np.frombuffer(buf, idt, k, offset)
        offset += idx.nbytes
        vals = np.frombuffer(buf, np.float32, k, offset)
        flat = np.zeros(size, np.float32)
        flat[idx] = vals
        return leaf_id, jnp.asarray(flat), end

    scale = np.float32(0.0)
    if mode in ("int8", "tern"):
        (scale,) = _SCALE.unpack_from(buf, offset)
        scale = np.float32(scale)
        offset += _SCALE.size
    idx = np.frombuffer(buf, idt, k, offset).astype(np.int32)
    offset += k * np.dtype(idt).itemsize
    if mode == "none":
        vals = np.frombuffer(buf, np.float32, k, offset).copy()
    elif mode == "bf16":
        vals = np.frombuffer(buf, np.uint16, k, offset) \
            .view(ml_dtypes.bfloat16).astype(np.float32)
    elif mode == "int8":
        vals = np.frombuffer(buf, np.int8, k, offset).astype(np.float32) \
            * scale
    else:  # tern
        codes = _unpack_tern(bytes(buf[offset:end]), k)
        vals = codes.astype(np.float32) * scale
    return leaf_id, SparseLeaf(values=jnp.asarray(vals),
                               indices=jnp.asarray(idx), size=size), end


def decode_message(payload) -> Message:
    buf = memoryview(payload)
    msg_type, sender, seq, aux, n_leaves = _ENVELOPE.unpack_from(buf, 0)
    offset = _ENVELOPE.size
    leaves = [None] * n_leaves
    for _ in range(n_leaves):
        leaf_id, leaf, offset = decode_leaf(buf, offset)
        leaves[leaf_id] = leaf
    return Message(type=msg_type, sender=sender, seq=seq, aux=aux,
                   leaves=leaves)
