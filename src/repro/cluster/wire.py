"""Packed binary wire codec for the federated cluster runtime.

Every message between a client and the coordinator is one *envelope*
followed by zero or more length-prefixed *leaf frames* (one per parameter
leaf, in ``jax.tree.leaves`` order):

    envelope:   u8  type      HELLO/WELCOME/UP/DOWN/SKIP/BYE
                u32 sender    client id (coordinator = 0xFFFFFFFF)
                u32 seq       per-sender sequence number (control types
                              reuse this field: HELLO = proposed id,
                              WELCOME = assigned worker slot)
                f32 aux       UP: the worker's scalar loss; else 0
                u32 n_leaves

    leaf frame: u32 frame_len (bytes after this field)
                u16 leaf_id
                u8  mode      value packing: 0 none / 1 bf16 / 2 int8 / 3 tern
                u8  kind      0 sparse COO / 1 dense f32 / 2 dense-as-COO
                u32 k         number of entries carried
                u32 size      dense length of the leaf
                [f32 scale]   int8/tern only: the per-message scale
                uN * k        indices (kinds 0 and 2); N derived from
                              ``size`` — u8 when size <= 256, u16 when
                              size <= 65536, u32 beyond — so the decoder
                              needs no extra field
                values        none: f32*k | bf16: u16*k | int8: i8*k
                              tern: 2-bit codes, 4 per byte
                              dense f32 (kind 1): f32*size, no indices

All integers little-endian.  Dense leaves always travel as f32 (quantizing
the model-difference would break the server's ``v_k == M`` invariant, Eq. 4);
the codec picks whichever of kind 1/2 is smaller for the actual nnz.

Quantization semantics are *exactly* ``sparsify.quantize_dequantize``:
``decode(encode(values, mode))`` reproduces ``quantize_dequantize(values,
mode)[0]`` bit-for-bit (tests/test_wire.py).  The same jitted quantizer is
exposed as :func:`quantize_message` and used by ``core.async_sim`` so the
simulator's arithmetic — and therefore its losses — is bit-identical to a
cluster run over this codec.

:func:`frame_bytes` computes the serialized size of a message from its
structure alone; it is definitionally equal to ``len(encode_message(...))``
and replaces the old analytic byte accounting everywhere.
"""
from __future__ import annotations

import struct
from typing import NamedTuple

import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.sparsify import SparseLeaf, quantize_parts as _quantize_parts

# message types
HELLO, WELCOME, UP, DOWN, SKIP, BYE = range(6)
TYPE_NAMES = {HELLO: "HELLO", WELCOME: "WELCOME", UP: "UP", DOWN: "DOWN",
              SKIP: "SKIP", BYE: "BYE"}
COORDINATOR_ID = 0xFFFFFFFF

# value packing modes (wire codes)
MODES = {"none": 0, "bf16": 1, "int8": 2, "tern": 3}
MODE_NAMES = {v: k for k, v in MODES.items()}

# leaf kinds
SPARSE, DENSE, DENSE_COO = 0, 1, 2

_ENVELOPE = struct.Struct("<BIIfI")     # 17 bytes
_LEN = struct.Struct("<I")              # 4-byte leaf frame length prefix
_HEADER = struct.Struct("<HBBII")       # 12-byte leaf header
_SCALE = struct.Struct("<f")


class Message(NamedTuple):
    type: int
    sender: int
    seq: int
    aux: float
    leaves: list  # [SparseLeaf | flat f32 jax array], leaf_id order


# ---------------------------------------------------------------------------
# quantization — sparsify.quantize_parts is the single implementation; the
# codec ships its (codes, scale) and async_sim applies its dequantized
# values, so both sides of the parity contract share one XLA program
# ---------------------------------------------------------------------------

def quantize_message(msgs, mode: str):
    """Apply wire quantization to every SparseLeaf of a message list.

    Dense leaves pass through untouched (they travel f32, see module doc).
    This is what the decoder on the far side will reconstruct; async_sim
    calls it in place of a real encode/decode round trip.
    """
    if mode == "none":
        return list(msgs)
    out = []
    for m in msgs:
        if isinstance(m, SparseLeaf):
            _, _, dq = _quantize_parts(m.values, mode)
            out.append(SparseLeaf(values=dq, indices=m.indices, size=m.size))
        else:
            out.append(m)
    return out


# ---------------------------------------------------------------------------
# size accounting — matches serialization by construction
# ---------------------------------------------------------------------------

def _value_nbytes(k: int, mode: str) -> int:
    return {"none": 4 * k, "bf16": 2 * k, "int8": k,
            "tern": (k + 3) // 4}[mode]


def index_dtype(size: int):
    """Narrowest unsigned index type for a ``size``-element leaf — derived
    from the header's ``size`` field, so it costs no wire bytes."""
    if size <= 1 << 8:
        return np.uint8
    if size <= 1 << 16:
        return np.uint16
    return np.uint32


def _index_nbytes(size: int) -> int:
    return np.dtype(index_dtype(size)).itemsize


def leaf_frame_bytes(k: int, size: int, mode: str, kind: int = SPARSE) -> int:
    """Serialized bytes of one leaf frame, length prefix included."""
    n = _LEN.size + _HEADER.size
    if kind == DENSE:
        return n + 4 * size
    if kind == DENSE_COO:
        return n + (4 + _index_nbytes(size)) * k
    if mode in ("int8", "tern"):
        n += _SCALE.size
    return n + _index_nbytes(size) * k + _value_nbytes(k, mode)


def _dense_kind(nnz: int, size: int) -> int:
    """COO when (idx, value) pairs beat the dense f32 vector."""
    return (DENSE_COO
            if (4 + _index_nbytes(size)) * nnz < 4 * size else DENSE)


def frame_bytes(msgs, *, mode: str = "none", envelope: bool = True) -> int:
    """Wire size of a message list — equal to ``len(encode_message(...))``.

    Replaces the old analytic accounting (``async_sim._msg_bytes`` /
    ``sparsify.message_bytes``): headers, per-message scales, and the
    bit-packed value widths are all counted exactly as serialized.
    """
    total = _ENVELOPE.size if envelope else 0
    for m in msgs:
        if isinstance(m, SparseLeaf):
            total += leaf_frame_bytes(m.k, m.size, mode, SPARSE)
        else:
            # count on-device: only the scalar nnz crosses to the host
            nnz = int(jnp.count_nonzero(m))
            size = int(m.size)
            total += leaf_frame_bytes(nnz, size, "none",
                                      _dense_kind(nnz, size))
    return total


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

def _pack_tern(codes: np.ndarray) -> bytes:
    """{-1, 0, +1} int8 -> 2-bit codes (two's complement), 4 per byte."""
    u = (codes.astype(np.int8) & 3).astype(np.uint8)
    pad = (-len(u)) % 4
    if pad:
        u = np.concatenate([u, np.zeros(pad, np.uint8)])
    u = u.reshape(-1, 4)
    return (u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4)
            | (u[:, 3] << 6)).astype(np.uint8).tobytes()


def _unpack_tern(buf: bytes, k: int) -> np.ndarray:
    b = np.frombuffer(buf, np.uint8)
    u = np.empty((len(b), 4), np.uint8)
    for j in range(4):
        u[:, j] = (b >> (2 * j)) & 3
    codes = u.reshape(-1)[:k].astype(np.int8)
    codes[codes == 3] = -1
    return codes


def encode_leaf(leaf_id: int, leaf, mode: str = "none"):
    """Serialize one leaf; returns ``(frame_bytes, shipped_leaf)``.

    ``shipped_leaf`` is exactly what :func:`decode_leaf` on the far side
    reconstructs (the dequantized SparseLeaf, or the dense array verbatim)
    — callers use it to keep local state consistent with the receiver.
    """
    if isinstance(leaf, SparseLeaf):
        codes, scale, dq = _quantize_parts(leaf.values, mode)
        k, size = leaf.k, leaf.size
        idx = np.asarray(leaf.indices).astype(index_dtype(size))
        if mode == "none":
            vals = np.asarray(codes, np.float32).tobytes()
        elif mode == "bf16":
            vals = np.asarray(codes).view(np.uint16).tobytes()
        elif mode == "int8":
            vals = np.asarray(codes).tobytes()
        else:  # tern
            vals = _pack_tern(np.asarray(codes))
        body = _HEADER.pack(leaf_id, MODES[mode], SPARSE, k, size)
        if mode in ("int8", "tern"):
            body += _SCALE.pack(float(scale))
        body += idx.tobytes() + vals
        shipped = SparseLeaf(values=dq, indices=leaf.indices, size=size)
        return _LEN.pack(len(body)) + body, shipped

    flat = np.asarray(leaf, np.float32).reshape(-1)
    nz = np.flatnonzero(flat)
    kind = _dense_kind(len(nz), flat.size)
    if kind == DENSE:
        body = _HEADER.pack(leaf_id, MODES["none"], DENSE,
                            flat.size, flat.size) + flat.tobytes()
    else:
        body = (_HEADER.pack(leaf_id, MODES["none"], DENSE_COO,
                             len(nz), flat.size)
                + nz.astype(index_dtype(flat.size)).tobytes()
                + flat[nz].tobytes())
    return _LEN.pack(len(body)) + body, leaf


def encode_message(msg_type: int, sender: int, seq: int, msgs=(),
                   *, mode: str = "none", aux: float = 0.0):
    """Serialize a full message; returns ``(payload, shipped_msgs)``."""
    frames, shipped = [], []
    for i, m in enumerate(msgs):
        frame, s = encode_leaf(i, m, mode)
        frames.append(frame)
        shipped.append(s)
    payload = _ENVELOPE.pack(msg_type, sender, seq, aux, len(frames))
    return payload + b"".join(frames), shipped


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_leaf(buf, offset: int = 0):
    """Decode one leaf frame; returns ``(leaf_id, leaf, next_offset)``."""
    (blen,) = _LEN.unpack_from(buf, offset)
    offset += _LEN.size
    end = offset + blen
    leaf_id, mode_c, kind, k, size = _HEADER.unpack_from(buf, offset)
    offset += _HEADER.size
    mode = MODE_NAMES[mode_c]

    idt = index_dtype(size)
    if kind == DENSE:
        flat = np.frombuffer(buf, np.float32, size, offset).copy()
        return leaf_id, jnp.asarray(flat), end
    if kind == DENSE_COO:
        idx = np.frombuffer(buf, idt, k, offset)
        offset += idx.nbytes
        vals = np.frombuffer(buf, np.float32, k, offset)
        flat = np.zeros(size, np.float32)
        flat[idx] = vals
        return leaf_id, jnp.asarray(flat), end

    scale = np.float32(0.0)
    if mode in ("int8", "tern"):
        (scale,) = _SCALE.unpack_from(buf, offset)
        scale = np.float32(scale)
        offset += _SCALE.size
    idx = np.frombuffer(buf, idt, k, offset).astype(np.int32)
    offset += k * np.dtype(idt).itemsize
    if mode == "none":
        vals = np.frombuffer(buf, np.float32, k, offset).copy()
    elif mode == "bf16":
        vals = np.frombuffer(buf, np.uint16, k, offset) \
            .view(ml_dtypes.bfloat16).astype(np.float32)
    elif mode == "int8":
        vals = np.frombuffer(buf, np.int8, k, offset).astype(np.float32) \
            * scale
    else:  # tern
        codes = _unpack_tern(bytes(buf[offset:end]), k)
        vals = codes.astype(np.float32) * scale
    return leaf_id, SparseLeaf(values=jnp.asarray(vals),
                               indices=jnp.asarray(idx), size=size), end


def decode_message(payload) -> Message:
    buf = memoryview(payload)
    msg_type, sender, seq, aux, n_leaves = _ENVELOPE.unpack_from(buf, 0)
    offset = _ENVELOPE.size
    leaves = [None] * n_leaves
    for _ in range(n_leaves):
        leaf_id, leaf, offset = decode_leaf(buf, offset)
        leaves[leaf_id] = leaf
    return Message(type=msg_type, sender=sender, seq=seq, aux=aux,
                   leaves=leaves)
