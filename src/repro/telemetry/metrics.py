"""In-graph flight-recorder metrics: fixed-shape counters + bounded
histograms carried on device through the event loops.

The hard constraint (DESIGN.md §11): telemetry must add ZERO host syncs to
the hot paths and must not perturb the data plane's bit-for-bit contract.
Both follow from the shape of this module:

* :class:`MetricsState` is a small fixed-shape pytree of int32 counters and
  log2-bucketed histograms.  Updating it is a handful of scatter-adds that
  only *read* stage outputs (messages, staleness, worker ids) — nothing
  feeds back into the data plane, so the training arithmetic is untouched.
* The serial and batched event loops update metrics in a SEPARATE jitted
  step (:func:`make_metrics_step`) after the data-plane stages, so the
  stage executables are literally the same compiled artifacts with metrics
  on or off.  The scan runner threads the state through its ``lax.scan``
  carry (reading only optimization-barrier-staged values).
* Every histogram is integer-valued and every bucket boundary is exact in
  both float32 and float64 (buckets split at powers of two), so the same
  event stream produces the SAME MetricsState in every runner — serial,
  batched, scan, or cluster.

The state is drained to host (:func:`drain`) only at eval boundaries or at
end of run; nothing here ever calls ``float()``/``np.asarray`` on a live
device value inside the event loop.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import SparseLeaf

# log2 buckets: bucket b holds integer x with floor(log2(x+1)) == b, i.e.
# x in [2^b - 1, 2^(b+1) - 2].  24 buckets cover x < 2^24 - 1 (16M events
# of staleness / nnz — the big-bench scale); larger values clip into the
# last bucket rather than growing the state.
N_BINS = 24

# update-magnitude buckets: bucket 0 is exactly-zero, bucket b >= 1 holds
# squared L2 norms with floor(log2(sq)) == b - 1 - MAG_OFFSET.  64 buckets
# starting at 2^-40 span vanishing tail updates up to 2^22-scale bursts.
MAG_BINS = 64
MAG_OFFSET = 40


class MetricsState(NamedTuple):
    """Fixed-shape on-device telemetry accumulator (one per run)."""

    n_events: jax.Array       # () int32 — events folded in so far
    per_worker: jax.Array     # (n_workers,) int32 — events per worker slot
    stale_hist: jax.Array     # (N_BINS,) int32 — per-event staleness
    up_nnz_hist: jax.Array    # (N_BINS,) int32 — shipped upward nnz
    down_nnz_hist: jax.Array  # (N_BINS,) int32 — shipped downward nnz
    mag_hist: jax.Array       # (MAG_BINS,) int32 — |G|^2 exponent buckets
    overflow: jax.Array       # () int32 — route/bucket entries dropped at
                              # a capacity slot (shard route kernel,
                              # shardedps W*cap bucket); 0 unless a caller
                              # tightens capacity below the safe bound


def init(n_workers: int) -> MetricsState:
    return MetricsState(
        n_events=jnp.zeros((), jnp.int32),
        per_worker=jnp.zeros((n_workers,), jnp.int32),
        stale_hist=jnp.zeros((N_BINS,), jnp.int32),
        up_nnz_hist=jnp.zeros((N_BINS,), jnp.int32),
        down_nnz_hist=jnp.zeros((N_BINS,), jnp.int32),
        mag_hist=jnp.zeros((MAG_BINS,), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )


def log2_bin(x, n_bins: int = N_BINS):
    """floor(log2(x+1)) clipped to [0, n_bins).  Exact at the power-of-two
    bucket boundaries in any float width, so host (float64) and device
    (float32) binning agree bit-for-bit on integer inputs < 2^24."""
    xf = jnp.maximum(x, 0).astype(jnp.float32)
    b = jnp.floor(jnp.log2(xf + 1.0)).astype(jnp.int32)
    return jnp.clip(b, 0, n_bins - 1)


def mag_bin(sq):
    """Exponent bucket of a squared L2 norm; 0 is reserved for exact zero."""
    sqf = sq.astype(jnp.float32)
    b = jnp.floor(jnp.log2(jnp.maximum(sqf, 2.0 ** (-MAG_OFFSET))))
    b = b.astype(jnp.int32) + jnp.int32(MAG_OFFSET + 1)
    return jnp.where(sqf > 0, jnp.clip(b, 1, MAG_BINS - 1), 0)


def msg_nnz(msg):
    """Shipped nnz of an (optionally batched) message.  Sparse messages
    have static frame occupancy k (what the codec prices); dense messages
    count true non-zeros along the arena axis."""
    if isinstance(msg, SparseLeaf):
        k = int(msg.values.shape[-1])
        return jnp.full(msg.values.shape[:-1], k, jnp.int32)
    return jnp.sum(msg != 0.0, axis=-1).astype(jnp.int32)


def msg_sqnorm(msg):
    """Squared L2 norm of an (optionally batched) message's values."""
    vals = msg.values if isinstance(msg, SparseLeaf) else msg
    return jnp.sum(vals.astype(jnp.float32) ** 2, axis=-1)


def update(ms: MetricsState, worker_ids, staleness, up_nnz, down_nnz,
           mag_sq, overflow=0) -> MetricsState:
    """Fold one event (scalars) or one batch (``(B,)`` arrays) in.

    Pure jnp scatter-adds — duplicate histogram buckets within a batch
    accumulate, so the result is identical to folding events one at a
    time (integer addition commutes).  ``overflow`` is the step's dropped
    route/bucket entry count (scalar or per-event array; summed in).
    """
    wid = jnp.asarray(worker_ids, jnp.int32)
    n = 1 if wid.ndim == 0 else int(wid.shape[0])
    return MetricsState(
        n_events=ms.n_events + jnp.int32(n),
        per_worker=ms.per_worker.at[wid].add(1),
        stale_hist=ms.stale_hist.at[log2_bin(jnp.asarray(staleness))].add(1),
        up_nnz_hist=ms.up_nnz_hist.at[log2_bin(up_nnz)].add(1),
        down_nnz_hist=ms.down_nnz_hist.at[log2_bin(down_nnz)].add(1),
        mag_hist=ms.mag_hist.at[mag_bin(mag_sq)].add(1),
        overflow=ms.overflow + jnp.sum(
            jnp.asarray(overflow, jnp.int32)).astype(jnp.int32),
    )


def make_metrics_step():
    """jit(metrics fold) for the python event loops: reads the SHIPPED
    up/down messages plus host-precomputed staleness, entirely outside the
    data-plane stage executables.  ``ms`` is donated — the accumulator
    updates in place, one extra dispatch per event (serial) or per batch
    (batched), zero host syncs."""

    def step(ms, worker_ids, staleness, up_msg, down_msg):
        return update(ms, worker_ids, staleness,
                      msg_nnz(up_msg), msg_nnz(down_msg),
                      msg_sqnorm(down_msg))

    return jax.jit(step, donate_argnums=(0,))


# ------------------------------------------------------------------ drain

def _bin_label(b: int) -> str:
    lo, hi = (1 << b) - 1, (1 << (b + 1)) - 2
    return str(lo) if lo == hi else f"{lo}-{hi}"


def _mag_label(b: int) -> str:
    if b == 0:
        return "0"
    e = b - 1 - MAG_OFFSET
    return f"2^{e}"


def hist_dict(counts, labeler=_bin_label) -> dict:
    """Histogram counts -> the JSON schema used by JSONL / BENCH artifacts:
    trailing-zero buckets trimmed, labels naming each bucket's range."""
    counts = [int(c) for c in np.asarray(counts)]
    last = max((i for i, c in enumerate(counts) if c), default=0)
    counts = counts[:last + 1]
    return {"bins": [labeler(b) for b in range(len(counts))],
            "counts": counts}


def drain(ms: MetricsState) -> dict:
    """Materialize the accumulator on host (the ONLY host sync telemetry
    performs — call at eval boundaries or end of run)."""
    return {
        "n_events": int(ms.n_events),
        "per_worker": np.asarray(ms.per_worker).tolist(),
        "staleness_hist": hist_dict(ms.stale_hist),
        "up_nnz_hist": hist_dict(ms.up_nnz_hist),
        "down_nnz_hist": hist_dict(ms.down_nnz_hist),
        "update_mag_hist": hist_dict(ms.mag_hist, labeler=_mag_label),
        "route_overflow": int(ms.overflow),
    }


def summarize_log2(x, n_bins: int = N_BINS) -> dict:
    """Host-side twin of the in-graph log2 histogram (same buckets, same
    schema) for values already on host — per-event byte sizes, staleness
    arrays, bench measurements."""
    x = np.maximum(np.asarray(x, np.float64), 0.0)
    b = np.clip(np.floor(np.log2(x + 1.0)).astype(np.int64), 0, n_bins - 1)
    return hist_dict(np.bincount(b, minlength=n_bins))
