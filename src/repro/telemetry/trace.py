"""Host-side flight recorder: Chrome-trace/Perfetto spans + JSONL events.

One :class:`Recorder` per run.  It buffers three things in memory and
writes them out on :meth:`close`:

* **spans** — ``with rec.span("coord/server_batch"): ...`` records a
  complete ("ph": "X") Chrome trace event with microsecond timestamps;
  ``rec.instant(...)`` records an instant ("ph": "i").  The whole buffer
  serializes to ``trace.json`` in the Chrome trace-event format, loadable
  by Perfetto / chrome://tracing.  Spans measure HOST wall-clock between
  enter and exit — for jitted stages that is dispatch time (JAX dispatch
  is async); the recorder never inserts device syncs to "fix" that.
* **events** — ``rec.event("run_summary", n_events=..., ...)`` appends one
  structured record to ``events.jsonl`` (one JSON object per line, each
  stamped with seconds-since-recorder-start ``t`` and a ``kind``).
* **counters** — ``rec.count("client/3/drops")`` bumps a named counter;
  the full counter map is flushed as a final ``{"kind": "counters"}``
  JSONL record so reports can build per-client tables.

All methods are thread-safe (the cluster runtime records from coordinator
and client threads) and cheap enough to leave in hot host loops; the
module-level :data:`NULL` recorder turns every call into a no-op so
runners can thread one object through unconditionally.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time

TRACE_FILE = "trace.json"
EVENTS_FILE = "events.jsonl"


class _Span:
    """Reusable span context; appends one complete event on exit."""

    __slots__ = ("rec", "name", "cat", "args", "t0")

    def __init__(self, rec, name, cat, args):
        self.rec, self.name, self.cat, self.args = rec, name, cat, args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.rec._complete(self.name, self.cat, self.t0,
                           time.perf_counter(), self.args)
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Recorder:
    """Buffering trace + JSONL recorder for one run."""

    enabled = True

    def __init__(self, run_dir: str | os.PathLike | None = None):
        self.run_dir = pathlib.Path(run_dir) if run_dir is not None else None
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._trace: list[dict] = []
        self._jsonl: list[str] = []
        self.counters: dict[str, float] = {}
        self._lock = threading.Lock()

    # -- spans -------------------------------------------------------------

    def span(self, name: str, cat: str = "run", **args) -> _Span:
        return _Span(self, name, cat, args or None)

    def _complete(self, name, cat, t0, t1, args):
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": round((t0 - self._t0) * 1e6, 3),
              "dur": round((t1 - t0) * 1e6, 3),
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self._trace.append(ev)

    def instant(self, name: str, cat: str = "run", **args) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": round((time.perf_counter() - self._t0) * 1e6, 3),
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self._trace.append(ev)

    # -- structured events -------------------------------------------------

    def event(self, kind: str, **fields) -> None:
        rec = {"t": round(time.perf_counter() - self._t0, 6), "kind": kind}
        rec.update(fields)
        line = json.dumps(rec, default=str)
        with self._lock:
            self._jsonl.append(line)

    def count(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    # -- lifecycle ---------------------------------------------------------

    def flush(self) -> list[str]:
        """Write ``trace.json`` + ``events.jsonl`` under ``run_dir`` (no-op
        without one); returns the paths written."""
        if self.run_dir is None:
            return []
        with self._lock:
            if self.counters:
                rec = {"t": round(time.perf_counter() - self._t0, 6),
                       "kind": "counters", "counters": dict(self.counters)}
                self._jsonl.append(json.dumps(rec))
            trace = list(self._trace)
            lines = list(self._jsonl)
        self.run_dir.mkdir(parents=True, exist_ok=True)
        tpath = self.run_dir / TRACE_FILE
        tpath.write_text(json.dumps(
            {"traceEvents": trace, "displayTimeUnit": "ms"}))
        epath = self.run_dir / EVENTS_FILE
        epath.write_text("".join(line + "\n" for line in lines))
        return [str(tpath), str(epath)]

    def close(self) -> list[str]:
        return self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullRecorder(Recorder):
    """Every method a no-op; the default recorder threaded through hot
    loops so call sites need no ``if`` guards."""

    enabled = False

    def __init__(self):
        self.run_dir = None
        self.counters = {}

    def span(self, name, cat="run", **args):
        return _NULL_SPAN

    def instant(self, name, cat="run", **args):
        pass

    def event(self, kind, **fields):
        pass

    def count(self, name, n=1):
        pass

    def flush(self):
        return []


NULL = NullRecorder()
