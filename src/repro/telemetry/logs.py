"""Leveled logging for the launchers — the ``print`` replacement.

``get_logger("train")`` returns a stdlib logger under the ``repro.``
namespace whose default handler writes BARE messages to stdout — so
``log.info("[train] done")`` is byte-identical to the ``print`` it
replaced and CLI output stays stable by default.  One knob silences or
routes everything:

* ``set_level("warning")`` / ``--log-level`` flag / ``REPRO_LOG`` env var
  — silence INFO chatter fleet-wide.
* ``set_log_file(path)`` / ``--log-file`` flag — mirror every record
  (timestamped + leveled) to a file.
* an active :class:`~repro.telemetry.trace.Recorder` installed via
  :func:`set_recorder` also receives every record as a structured
  ``{"kind": "log"}`` JSONL event.
"""
from __future__ import annotations

import logging
import os
import sys

_ROOT_NAME = "repro"
_configured = False
_active_recorder = None


class _StdoutHandler(logging.StreamHandler):
    """StreamHandler that resolves ``sys.stdout`` at EMIT time, so stream
    redirection after configuration (contextlib.redirect_stdout, pytest's
    capsys) is honored."""

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):
        pass


class _RecorderHandler(logging.Handler):
    """Mirror log records into the active recorder's JSONL event log."""

    def emit(self, record: logging.LogRecord) -> None:
        rec = _active_recorder
        if rec is not None and rec.enabled:
            rec.event("log", level=record.levelname.lower(),
                      logger=record.name.removeprefix(_ROOT_NAME + "."),
                      msg=record.getMessage())


def _configure() -> logging.Logger:
    global _configured
    root = logging.getLogger(_ROOT_NAME)
    if _configured:
        return root
    out = _StdoutHandler()
    out.setFormatter(logging.Formatter("%(message)s"))
    root.addHandler(out)
    root.addHandler(_RecorderHandler())
    root.setLevel(_parse_level(os.environ.get("REPRO_LOG", "info")))
    root.propagate = False
    _configured = True
    return root


def _parse_level(level: str | int) -> int:
    if isinstance(level, int):
        return level
    value = logging.getLevelName(str(level).upper())
    if not isinstance(value, int):
        raise ValueError(f"unknown log level {level!r}")
    return value


def get_logger(name: str = "") -> logging.Logger:
    """A leveled logger; default output is bare messages on stdout."""
    root = _configure()
    return root.getChild(name) if name else root


def set_level(level: str | int) -> None:
    """One flag to silence/route the launchers: 'debug' | 'info' |
    'warning' | 'error' | 'critical' (or a numeric level)."""
    _configure().setLevel(_parse_level(level))


def set_log_file(path: str) -> None:
    """Additionally mirror records (timestamped) to ``path``."""
    handler = logging.FileHandler(path)
    handler.setFormatter(logging.Formatter(
        "%(asctime)s %(levelname)s %(name)s: %(message)s"))
    _configure().addHandler(handler)


def set_recorder(recorder) -> None:
    """Route log records into ``recorder``'s JSONL stream (None detaches)."""
    global _active_recorder
    _configure()
    _active_recorder = recorder
