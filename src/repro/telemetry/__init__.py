"""Flight-recorder telemetry for every data-plane view (DESIGN.md §11).

Three pieces, importable as one package:

* :mod:`repro.telemetry.metrics` — in-graph :class:`MetricsState`: a
  fixed-shape pytree of counters + log2-bucketed histograms (staleness,
  up/down nnz, update magnitude, per-worker events) updated on device with
  zero host syncs and drained only at eval boundaries.
* :mod:`repro.telemetry.trace` — host-side :class:`Recorder`: Chrome
  trace-event / Perfetto spans (``trace.json``) plus a structured JSONL
  event log (``events.jsonl``); :data:`NULL` is the free no-op default.
* :mod:`repro.telemetry.logs` — the leveled ``log`` facility replacing
  bare prints in the launchers (bare-message stdout by default, one flag
  to silence or route).

The contract every runner honors: telemetry OFF is the untouched pre-
telemetry code path (identical compiled artifacts), telemetry ON changes
no data-plane bit (tests/test_async_sim.py::test_metrics_do_not_change_bits).
"""
from . import metrics
from .metrics import MetricsState
from .logs import get_logger, set_level, set_log_file, set_recorder
from .trace import NULL, NullRecorder, Recorder

__all__ = [
    "metrics", "MetricsState",
    "Recorder", "NullRecorder", "NULL",
    "get_logger", "set_level", "set_log_file", "set_recorder",
]
