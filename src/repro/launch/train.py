"""Training launcher.

Two modes:

* ``--smoke`` (default on CPU): reduced variant of the chosen architecture
  on a small host mesh — runs REAL steps and prints losses.  This is the
  end-to-end driver used by examples/ and CI.
* production: full config on the production mesh (requires a TPU slice; on
  CPU use ``repro.launch.dryrun`` instead, which compiles but does not run).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --smoke \
        --steps 30 --mode allgather --density 0.05
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--mode", default="allgather",
                    choices=["dense", "allgather", "shardedps"])
    ap.add_argument("--density", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "exact", "sampled", "blockwise"],
                    help="top-k compression engine (core/engine.py)")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "bf16", "int8", "tern"],
                    help="wire quantization of sparse message values")
    ap.add_argument("--sampled-above", type=int, default=1 << 20,
                    help="auto engine: sampled threshold for leaves/rows "
                         "with at least this many elements")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=8,
                    help="host device override for the smoke mesh")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-level", default=None,
                    help="silence/route launcher output: debug | info | "
                         "warning | error (default: REPRO_LOG env or info)")
    ap.add_argument("--log-file", default=None,
                    help="mirror launcher output (timestamped) to a file")
    args = ap.parse_args()

    from repro import telemetry

    log = telemetry.get_logger("train")
    if args.log_level:
        telemetry.set_level(args.log_level)
    if args.log_file:
        telemetry.set_log_file(args.log_file)

    if args.smoke and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import save_checkpoint
    from repro.configs import get_arch
    from repro.configs.shapes import InputShape, input_specs
    from repro.core.distributed import ExchangeConfig
    from repro.data.synthetic import TokenStream
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_train_step, zeros_state
    from repro.models import init_params

    from repro.compat import supports_partial_auto_shard_map

    cfg = get_arch(args.arch).reduced()
    n_dev = jax.device_count()
    model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    if not supports_partial_auto_shard_map():
        # train data-parallel only; model parallelism needs jax >= 0.5
        model_par = 1
    mesh = mesh_lib.make_mesh((n_dev // model_par, model_par),
                              ("data", "model"))
    W = n_dev // model_par
    log.info(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} "
             f"mode={args.mode} density={args.density} engine={args.engine} "
             f"quantize={args.quantize}")

    shape = InputShape("smoke", args.seq, args.batch, "train")
    ex_cfg = ExchangeConfig(mode=args.mode, density=args.density,
                            momentum=args.momentum, engine=args.engine,
                            quantize=args.quantize,
                            sampled_threshold_above=args.sampled_above)
    bundle = build_train_step(cfg, mesh, ex_cfg, lr=args.lr,
                              batch_specs_abstract=input_specs(cfg, shape),
                              remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ex_state = zeros_state(bundle)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch_size=args.batch, seed=0)
    with mesh:
        step = bundle.jit()
        for i in range(args.steps):
            batch = stream.batch(i)
            if cfg.frontend_tokens:
                batch["frontend_embeds"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(1), i),
                    (args.batch, cfg.frontend_tokens, cfg.d_model),
                    cfg.cdtype)
            params, ex_state, loss = step(params, ex_state, batch)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                log.info(f"  step {i:4d} loss={float(loss):.4f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        log.info(f"[train] saved {args.checkpoint}")
    log.info("[train] done")


if __name__ == "__main__":
    main()
