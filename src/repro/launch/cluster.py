"""Launch a real multi-process federated cluster over TCP.

    PYTHONPATH=src python -m repro.launch.cluster --clients 4 --rounds 20
    PYTHONPATH=src python -m repro.launch.cluster --smoke

The main process runs the coordinator; each client is a separate OS process
(``--role client`` re-invocations of this module) connecting over a real
socket, so every gradient crosses the packed wire codec and the printed
up/down numbers are *measured* bytes, not a formula.  All processes rebuild
the identical problem (MLP on the gaussian-blobs task, optionally Dirichlet
non-IID sharded) from the shared ``--seed``; nothing but wire frames moves
between them.

``--smoke`` is the CI guard for the multiprocess path: 2 clients, a few
int8-quantized rounds, asserts the loss dropped, and exits nonzero on any
hang (every stage is timeout-bounded).
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import make_strategy
from repro.core.engine import CompressionSpec
from repro.data.synthetic import ClassificationTask

log = telemetry.get_logger("cluster")


def _problem(args):
    """Deterministic shared problem — identical in every process."""
    task = ClassificationTask(n_features=args.features,
                              n_classes=args.classes,
                              batch_size=args.batch_size,
                              noise=0.6, seed=args.seed)
    if args.alpha > 0:
        from repro.cluster.scenarios import NonIIDClassification
        data = NonIIDClassification(task=task, alpha=args.alpha,
                                    shard_seed=args.seed,
                                    n_clients=args.clients)
    else:
        data = task

    k1, k2 = jax.random.split(jax.random.PRNGKey(args.seed))
    h = args.hidden
    params0 = {
        "w1": jax.random.normal(k1, (args.features, h)) * 0.2,
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(k2, (h, args.classes)) * 0.2,
        "b2": jnp.zeros((args.classes,)),
    }

    def apply(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    def grad_fn(p, batch):
        x, y = batch

        def loss(p):
            lp = jax.nn.log_softmax(apply(p, x))
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(p)

    def batch_fn(e, k):
        return data.batch(int(e), int(k) % args.clients)

    def accuracy(p):
        x, y = task.eval_set(512)
        return float(jnp.mean(jnp.argmax(apply(p, x), -1) == y))

    return params0, grad_fn, batch_fn, accuracy


def _strategy(args):
    kw = {}
    if args.strategy != "asgd":
        kw["density"] = args.density
    if args.strategy in ("dgs", "dgc_async"):
        kw["momentum"] = args.momentum
    if args.strategy != "asgd":
        kw["quantize"] = args.quantize
    return make_strategy(args.strategy, **kw)


def run_client(args):
    from repro.cluster.client import ClusterClient
    from repro.cluster.scenarios import ClientPlan
    from repro.cluster.transport import TcpClientTransport

    params0, grad_fn, batch_fn, _ = _problem(args)
    transport = TcpClientTransport(args.host, args.port, args.client_id,
                                   connect_timeout=args.timeout)
    client = ClusterClient(
        transport=transport,
        strategy=_strategy(args),
        grad_fn=grad_fn,
        params0=params0,
        batch_fn=batch_fn,
        plan=ClientPlan(client_id=args.client_id, n_rounds=args.rounds,
                        participation=args.participation, seed=args.seed),
        lr=args.lr,
        reply_timeout=args.timeout,
        max_retries=3,
    )
    client.run()
    transport.close()
    return 0


def run_coordinator(args, *, spawn_clients: bool):
    from repro.cluster.coordinator import Coordinator
    from repro.cluster.transport import TcpCoordinatorTransport

    params0, grad_fn, _, accuracy = _problem(args)
    recorder = (telemetry.Recorder(args.trace_dir)
                if args.trace_dir else telemetry.NULL)
    if recorder.enabled:
        telemetry.set_recorder(recorder)
    transport = TcpCoordinatorTransport(args.host, args.port)
    log.info(f"[coordinator] listening on {transport.host}:{transport.port} "
             f"({args.clients} clients x {args.rounds} rounds)")
    procs = []
    if spawn_clients:
        for c in range(args.clients):
            cmd = [sys.executable, "-m", "repro.launch.cluster",
                   "--role", "client", "--client-id", str(c),
                   "--port", str(transport.port)] + _shared_flags(args)
            procs.append(subprocess.Popen(cmd))

    spec = CompressionSpec(engine="exact", quantize=args.secondary_quantize)
    coordinator = Coordinator(
        transport=transport,
        params0=params0,
        n_slots=args.clients,
        secondary_density=args.secondary_density,
        secondary_spec=spec,
        recv_timeout=args.timeout,
        recorder=recorder,
    )
    t0 = time.perf_counter()
    try:
        with recorder.span("cluster/serve"):
            final, hist = coordinator.serve()
        dt = time.perf_counter() - t0
    finally:
        # on any serve() failure, still reap the children + free the port
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        transport.close()

    n = max(1, len(hist.losses))
    log.info(f"[coordinator] {len(hist.losses)} events in {dt:.1f}s | "
             f"loss {hist.losses[:3].mean():.4f} -> "
             f"{hist.losses[-3:].mean():.4f} | acc {accuracy(final):.3f}")
    log.info(f"[coordinator] measured wire bytes: up={hist.up_bytes} "
             f"({hist.up_bytes / n:.0f}/event) down={hist.down_bytes} "
             f"({hist.down_bytes / n:.0f}/event)")
    if recorder.enabled:
        telemetry.set_recorder(None)
        paths = recorder.close()
        log.info(f"[coordinator] telemetry: {' '.join(paths)}")
    if args.smoke:
        assert len(hist.losses) == args.clients * args.rounds, \
            "smoke: missing events"
        assert hist.losses[-3:].mean() < hist.losses[:3].mean(), \
            "smoke: loss did not decrease"
        assert hist.up_bytes > 0 and hist.down_bytes > 0
        log.info("[coordinator] smoke OK")
    return 0


def _shared_flags(args) -> list[str]:
    return ["--clients", str(args.clients), "--rounds", str(args.rounds),
            "--strategy", args.strategy, "--density", str(args.density),
            "--momentum", str(args.momentum), "--quantize", args.quantize,
            "--lr", str(args.lr), "--seed", str(args.seed),
            "--features", str(args.features), "--classes", str(args.classes),
            "--hidden", str(args.hidden), "--batch-size",
            str(args.batch_size), "--alpha", str(args.alpha),
            "--participation", str(args.participation),
            "--host", args.host, "--timeout", str(args.timeout)]


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--role", choices=("auto", "coordinator", "client"),
                   default="auto")
    p.add_argument("--smoke", action="store_true",
                   help="tiny timeout-guarded 2-process CI run")
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--rounds", type=int, default=20)
    p.add_argument("--client-id", type=int, default=0)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--strategy", default="dgs")
    p.add_argument("--density", type=float, default=0.05)
    p.add_argument("--momentum", type=float, default=0.7)
    p.add_argument("--quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--secondary-density", type=float, default=None)
    p.add_argument("--secondary-quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--lr", type=float, default=0.1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--alpha", type=float, default=0.0,
                   help="Dirichlet non-IID concentration (0 = IID)")
    p.add_argument("--participation", type=float, default=1.0)
    p.add_argument("--features", type=int, default=32)
    p.add_argument("--classes", type=int, default=8)
    p.add_argument("--hidden", type=int, default=32)
    p.add_argument("--batch-size", type=int, default=32)
    p.add_argument("--timeout", type=float, default=60.0)
    p.add_argument("--trace-dir", default=None,
                   help="write trace.json + events.jsonl (flight recorder) "
                        "under this directory — coordinator role only")
    p.add_argument("--log-level", default=None,
                   help="silence/route launcher output: debug | info | "
                        "warning | error (default: REPRO_LOG env or info)")
    p.add_argument("--log-file", default=None,
                   help="mirror launcher output (timestamped) to a file")
    args = p.parse_args(argv)
    if args.log_level:
        telemetry.set_level(args.log_level)
    if args.log_file:
        telemetry.set_log_file(args.log_file)

    if args.smoke:
        args.clients, args.rounds = 2, 6
        args.strategy, args.density, args.quantize = "dgs", 0.1, "int8"
        args.secondary_density = 0.2
        args.lr = 0.1

    if args.role == "client":
        return run_client(args)
    return run_coordinator(args, spawn_clients=args.role == "auto")


if __name__ == "__main__":
    sys.exit(main())
