"""Launch a real multi-process federated cluster over TCP.

    PYTHONPATH=src python -m repro.launch.cluster --clients 4 --rounds 20
    PYTHONPATH=src python -m repro.launch.cluster --clients 4 --shards 2
    PYTHONPATH=src python -m repro.launch.cluster --smoke [--shards 2]

The main process runs the coordinator; each client is a separate OS process
(``--role client`` re-invocations of this module) connecting over a real
socket, so every gradient crosses the packed wire codec and the printed
up/down numbers are *measured* bytes, not a formula.  All processes rebuild
the identical problem (MLP on the gaussian-blobs task, optionally Dirichlet
non-IID sharded) from the shared ``--seed``; nothing but wire frames moves
between them.

``--shards S`` range-partitions the parameter arena across S coordinator
shards (DESIGN.md §12), each listening on its own port; clients connect to
every shard (``--ports p0,p1,...``), split each upward frame by index
range, and merge the per-shard downward diffs.  Sharded runs serve clients
in a LOCKSTEP round-robin schedule so every shard sees the identical event
order — which makes an S-shard run reproduce the 1-shard run's losses and
final parameters bit-for-bit (disjoint-range scatter-adds commute).

``--mesh-shards S`` runs the same range partition as ONE coordinator
hosting all S shard arenas in-graph (DESIGN.md §14): the stacked mesh
server stages route every message through the alltoallv exchange, clients
connect to a single ordinary port, and both losses/params AND measured
wire bytes reproduce the unsharded run bit-for-bit.  Uses one JAX device
per shard when available (``XLA_FLAGS=--xla_force_host_platform_device_``
``count=S`` on CPU); otherwise the bit-identical single-device fallback.
Mutually exclusive with ``--shards``.

``--smoke`` is the CI guard for the multiprocess path: 2 clients, a few
int8-quantized rounds, asserts the loss dropped, and exits nonzero on any
hang (every stage is timeout-bounded).  With ``--shards S`` (or
``--mesh-shards S``) the smoke run first serves a 1-shard lockstep
reference, then the sharded run, and asserts their losses and final
parameters are bit-identical (for mesh runs, the measured bytes too).
"""
from __future__ import annotations

import argparse
import atexit
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import make_strategy
from repro.core.engine import CompressionSpec
from repro.data.synthetic import ClassificationTask

log = telemetry.get_logger("cluster")

# every child this launcher spawns, so nothing is orphaned when the
# launcher dies mid-run (e.g. `timeout` sending SIGTERM to a hung smoke —
# the finally-block cleanup never runs on an unhandled signal)
_CHILDREN: list[subprocess.Popen] = []


def spawn(cmd) -> subprocess.Popen:
    """``Popen`` tracked for reaping by :func:`reap_children`."""
    proc = subprocess.Popen(cmd)
    _CHILDREN.append(proc)
    return proc


def reap_children(timeout: float = 5.0):
    """Terminate -> wait -> kill every live tracked child."""
    live = [p for p in _CHILDREN if p.poll() is None]
    for p in live:
        try:
            p.terminate()
        except OSError:
            pass
    deadline = time.monotonic() + timeout
    for p in live:
        try:
            p.wait(timeout=max(0.1, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            p.kill()
    _CHILDREN.clear()


def install_reaper():
    """Reap children on normal exit AND on SIGTERM/SIGINT.

    ``timeout``(1) kills a hung smoke with SIGTERM; without a handler the
    client processes (blocked on their sockets) outlive the launcher.
    The handler re-exits with the conventional 128+signum code.
    """
    atexit.register(reap_children)

    def _on_signal(signum, frame):
        reap_children()
        sys.exit(128 + signum)

    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, _on_signal)
        except ValueError:
            pass   # not the main thread (embedded use): atexit still runs


def _problem(args):
    """Deterministic shared problem — identical in every process."""
    task = ClassificationTask(n_features=args.features,
                              n_classes=args.classes,
                              batch_size=args.batch_size,
                              noise=0.6, seed=args.seed)
    if args.alpha > 0:
        from repro.cluster.scenarios import NonIIDClassification
        data = NonIIDClassification(task=task, alpha=args.alpha,
                                    shard_seed=args.seed,
                                    n_clients=args.clients)
    else:
        data = task

    k1, k2 = jax.random.split(jax.random.PRNGKey(args.seed))
    h = args.hidden
    params0 = {
        "w1": jax.random.normal(k1, (args.features, h)) * 0.2,
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(k2, (h, args.classes)) * 0.2,
        "b2": jnp.zeros((args.classes,)),
    }

    def apply(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    def grad_fn(p, batch):
        x, y = batch

        def loss(p):
            lp = jax.nn.log_softmax(apply(p, x))
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(p)

    def batch_fn(e, k):
        return data.batch(int(e), int(k) % args.clients)

    def accuracy(p):
        x, y = task.eval_set(512)
        return float(jnp.mean(jnp.argmax(apply(p, x), -1) == y))

    return params0, grad_fn, batch_fn, accuracy


def _strategy(args):
    kw = {}
    if args.strategy != "asgd":
        kw["density"] = args.density
    if args.strategy in ("dgs", "dgc_async"):
        kw["momentum"] = args.momentum
    if args.strategy != "asgd":
        kw["quantize"] = args.quantize
    return make_strategy(args.strategy, **kw)


def run_client(args):
    from repro.cluster.client import ClusterClient
    from repro.cluster.scenarios import ClientPlan
    from repro.cluster.transport import TcpClientTransport
    from repro.core.paramspace import ParamSpace, ShardSpec

    params0, grad_fn, batch_fn, _ = _problem(args)
    ports = ([int(x) for x in args.ports.split(",")] if args.ports
             else [args.port])
    transports = [TcpClientTransport(args.host, pt, args.client_id,
                                     connect_timeout=args.timeout)
                  for pt in ports]
    # every process derives the same ShardSpec from the same params0, so
    # client-side splitting and coordinator-side ownership always agree
    shard_spec = (ShardSpec.for_space(ParamSpace.from_tree(params0),
                                      len(ports))
                  if len(ports) > 1 else None)
    client = ClusterClient(
        transport=transports if len(transports) > 1 else transports[0],
        shard_spec=shard_spec,
        pin_slot=args.pin_slot,
        strategy=_strategy(args),
        grad_fn=grad_fn,
        params0=params0,
        batch_fn=batch_fn,
        plan=ClientPlan(client_id=args.client_id, n_rounds=args.rounds,
                        participation=args.participation, seed=args.seed),
        lr=args.lr,
        reply_timeout=args.timeout,
        max_retries=3,
    )
    client.run()
    for t in transports:
        t.close()
    return 0


def _serve_cluster(args, params0, *, spawn_clients: bool, n_shards: int,
                   recorder, lockstep: bool | None = None,
                   mesh_shards: int = 0):
    """One coordinator-side run (1 or S shards); returns (final, hist, dt).

    ``lockstep`` serves clients in an explicit round-robin schedule
    (client 0..C-1, repeated ``rounds`` times) instead of arrival order —
    the determinism sharded runs need so every shard sees the identical
    event order (and the 1-shard reference a ``--smoke --shards`` run is
    compared against sees it too).  Defaults to ``n_shards > 1`` or
    ``mesh_shards > 0``.  ``mesh_shards = S`` keeps ONE transport/port and
    hands the S-way range partition to the coordinator's in-graph mesh
    stages (clients are oblivious).
    """
    from repro.cluster.coordinator import Coordinator
    from repro.cluster.transport import (ScheduleDriven,
                                         TcpCoordinatorTransport)
    from repro.core.paramspace import ParamSpace, ShardSpec

    if lockstep is None:
        lockstep = n_shards > 1 or mesh_shards > 0
    transports = [TcpCoordinatorTransport(args.host,
                                          args.port if s == 0 else 0)
                  for s in range(n_shards)]
    ports = [t.port for t in transports]
    log.info(f"[coordinator] listening on {transports[0].host}:"
             f"{','.join(str(p) for p in ports)} ({args.clients} clients x "
             f"{args.rounds} rounds, {n_shards} shard(s))")
    procs = []
    if spawn_clients:
        for c in range(args.clients):
            cmd = [sys.executable, "-m", "repro.launch.cluster",
                   "--role", "client", "--client-id", str(c),
                   "--ports", ",".join(str(p) for p in ports)] \
                + _shared_flags(args)
            if lockstep:
                cmd.append("--pin-slot")
            procs.append(spawn(cmd))

    shard_spec = (ShardSpec.for_space(ParamSpace.from_tree(params0),
                                      n_shards)
                  if n_shards > 1 else None)
    spec = CompressionSpec(engine="exact", quantize=args.secondary_quantize)
    order = np.tile(np.arange(args.clients), args.rounds)
    coords = [Coordinator(
        transport=transports[s],
        params0=params0,
        n_slots=args.clients,
        secondary_density=args.secondary_density,
        secondary_spec=spec,
        scheduler=ScheduleDriven(order) if lockstep else None,
        recv_timeout=args.timeout,
        recorder=recorder,
        shard_spec=shard_spec,
        shard_id=s,
        mesh_shards=mesh_shards,
    ) for s in range(n_shards)]

    results: list = [None] * n_shards
    errors: list = []

    def _serve(s):
        try:
            results[s] = coords[s].serve()
        except Exception as exc:
            errors.append(exc)

    shard_threads = [threading.Thread(target=_serve, args=(s,), daemon=True)
                     for s in range(1, n_shards)]
    t0 = time.perf_counter()
    try:
        with recorder.span("cluster/serve"):
            for t in shard_threads:
                t.start()
            final, hist = coords[0].serve()
            for t in shard_threads:
                t.join(timeout=args.timeout)
        if errors:
            raise errors[0]
        dt = time.perf_counter() - t0
    finally:
        # on any serve() failure, still reap the children + free the ports
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
            finally:
                if p in _CHILDREN:
                    _CHILDREN.remove(p)
        for t in transports:
            t.close()

    if n_shards > 1:
        # stitch shard results: shard 0's History is the event log (every
        # shard served the identical lockstep order), bytes sum across
        # shards, shard/{i}/* counters merge, per-shard leaves concatenate
        results[0] = (final, hist)
        leaves = [leaf for f, _ in results for leaf in jax.tree.leaves(f)]
        final = jax.tree.unflatten(jax.tree.structure(params0), leaves)
        counters = dict(hist.metrics["counters"])
        for _, h in results[1:]:
            counters.update({k: v for k, v in h.metrics["counters"].items()
                             if k.startswith("shard/")})
        hist = hist._replace(
            up_bytes=sum(h.up_bytes for _, h in results),
            down_bytes=sum(h.down_bytes for _, h in results),
            metrics={**hist.metrics, "counters": counters})
    return final, hist, dt


def run_coordinator(args, *, spawn_clients: bool):
    params0, grad_fn, _, accuracy = _problem(args)
    recorder = (telemetry.Recorder(args.trace_dir)
                if args.trace_dir else telemetry.NULL)
    if recorder.enabled:
        telemetry.set_recorder(recorder)

    ref_hist = ref_final = None
    if args.smoke and (args.shards > 1 or args.mesh_shards > 0):
        # the bit-parity reference: same problem, same lockstep order,
        # ONE unsharded server — the sharded run below must reproduce it
        ref_final, ref_hist, _ = _serve_cluster(
            args, params0, spawn_clients=spawn_clients, n_shards=1,
            recorder=telemetry.NULL, lockstep=True)

    final, hist, dt = _serve_cluster(
        args, params0, spawn_clients=spawn_clients, n_shards=args.shards,
        recorder=recorder, mesh_shards=args.mesh_shards)

    n = max(1, len(hist.losses))
    log.info(f"[coordinator] {len(hist.losses)} events in {dt:.1f}s | "
             f"loss {hist.losses[:3].mean():.4f} -> "
             f"{hist.losses[-3:].mean():.4f} | acc {accuracy(final):.3f}")
    log.info(f"[coordinator] measured wire bytes: up={hist.up_bytes} "
             f"({hist.up_bytes / n:.0f}/event) down={hist.down_bytes} "
             f"({hist.down_bytes / n:.0f}/event)")
    if recorder.enabled:
        telemetry.set_recorder(None)
        paths = recorder.close()
        log.info(f"[coordinator] telemetry: {' '.join(paths)}")
    if args.smoke:
        assert len(hist.losses) == args.clients * args.rounds, \
            "smoke: missing events"
        assert hist.losses[-3:].mean() < hist.losses[:3].mean(), \
            "smoke: loss did not decrease"
        assert hist.up_bytes > 0 and hist.down_bytes > 0
        if ref_hist is not None:
            assert np.array_equal(hist.losses, ref_hist.losses), \
                "smoke: sharded losses diverged from 1-shard reference"
            for a, b in zip(jax.tree.leaves(final),
                            jax.tree.leaves(ref_final)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    "smoke: sharded params diverged from 1-shard reference"
            if args.mesh_shards > 0:
                # the mesh contract is stronger: one coordinator, one wire
                # frame per event — measured bytes match the reference too
                assert (hist.up_bytes, hist.down_bytes) == \
                    (ref_hist.up_bytes, ref_hist.down_bytes), \
                    "smoke: mesh-sharded bytes diverged from reference"
                label = f"{args.mesh_shards}-mesh-shard"
            else:
                label = f"{args.shards}-shard"
            log.info(f"[coordinator] smoke OK: {label} run "
                     f"bit-identical to 1-shard reference")
        else:
            log.info("[coordinator] smoke OK")
    return 0


def _shared_flags(args) -> list[str]:
    return ["--clients", str(args.clients), "--rounds", str(args.rounds),
            "--strategy", args.strategy, "--density", str(args.density),
            "--momentum", str(args.momentum), "--quantize", args.quantize,
            "--lr", str(args.lr), "--seed", str(args.seed),
            "--features", str(args.features), "--classes", str(args.classes),
            "--hidden", str(args.hidden), "--batch-size",
            str(args.batch_size), "--alpha", str(args.alpha),
            "--participation", str(args.participation),
            "--host", args.host, "--timeout", str(args.timeout)]


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--role", choices=("auto", "coordinator", "client"),
                   default="auto")
    p.add_argument("--smoke", action="store_true",
                   help="tiny timeout-guarded multi-process CI run; with "
                        "--shards S it first runs a 1-shard reference and "
                        "asserts the sharded run is bit-identical")
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--rounds", type=int, default=20)
    p.add_argument("--client-id", type=int, default=0)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--shards", type=int, default=1,
                   help="coordinator shards: range-partition the parameter "
                        "arena across S servers, one port each (lockstep "
                        "round-robin serving; bit-identical to --shards 1)")
    p.add_argument("--mesh-shards", type=int, default=0,
                   help="in-graph device-mesh shards: ONE coordinator runs "
                        "all S shard arenas inside a single shard_mapped "
                        "server stage (DESIGN.md §14); one port, clients "
                        "unchanged, bytes AND losses bit-identical to the "
                        "unsharded run (exclusive with --shards)")
    p.add_argument("--ports", default=None,
                   help="client role: comma-separated coordinator shard "
                        "ports, shard order (overrides --port)")
    p.add_argument("--pin-slot", action="store_true",
                   help="client role: claim worker slot == client id "
                        "(lockstep runs need every shard to agree)")
    p.add_argument("--strategy", default="dgs")
    p.add_argument("--density", type=float, default=0.05)
    p.add_argument("--momentum", type=float, default=0.7)
    p.add_argument("--quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--secondary-density", type=float, default=None)
    p.add_argument("--secondary-quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--lr", type=float, default=0.1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--alpha", type=float, default=0.0,
                   help="Dirichlet non-IID concentration (0 = IID)")
    p.add_argument("--participation", type=float, default=1.0)
    p.add_argument("--features", type=int, default=32)
    p.add_argument("--classes", type=int, default=8)
    p.add_argument("--hidden", type=int, default=32)
    p.add_argument("--batch-size", type=int, default=32)
    p.add_argument("--timeout", type=float, default=60.0)
    p.add_argument("--trace-dir", default=None,
                   help="write trace.json + events.jsonl (flight recorder) "
                        "under this directory — coordinator role only")
    p.add_argument("--log-level", default=None,
                   help="silence/route launcher output: debug | info | "
                        "warning | error (default: REPRO_LOG env or info)")
    p.add_argument("--log-file", default=None,
                   help="mirror launcher output (timestamped) to a file")
    args = p.parse_args(argv)
    if args.mesh_shards and args.shards > 1:
        p.error("--shards and --mesh-shards are two different sharding "
                "runtimes (S coordinator processes vs one in-graph mesh "
                "stage) — pass exactly one of them")
    if args.log_level:
        telemetry.set_level(args.log_level)
    if args.log_file:
        telemetry.set_log_file(args.log_file)
    install_reaper()

    if args.smoke:
        args.clients, args.rounds = 2, 6
        args.strategy, args.density, args.quantize = "dgs", 0.1, "int8"
        args.secondary_density = 0.2
        args.lr = 0.1

    if args.role == "client":
        return run_client(args)
    return run_coordinator(args, spawn_clients=args.role == "auto")


if __name__ == "__main__":
    sys.exit(main())
