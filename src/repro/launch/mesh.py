"""Production mesh construction.

Target hardware: TPU v5e, 256 chips per pod (16x16), optionally 2 pods.
``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.

``axis_types`` (all-Auto, so GSPMD owns the "model" axis) is only passed on
jax versions that have it — jax 0.4.x has neither the kwarg nor
``jax.sharding.AxisType`` (see repro.compat).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **make_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **make_mesh_kwargs(len(axes)))


def data_axis_names(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def n_data_workers(mesh) -> int:
    return int(
        __import__("math").prod(
            mesh.shape[n] for n in data_axis_names(mesh)))


def model_axis_size(mesh) -> int:
    return int(mesh.shape.get("model", 1))
