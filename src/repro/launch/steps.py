"""Step builders: jitted train/prefill/serve steps for (arch, mesh, mode).

train_step topology (DESIGN.md §4):

    jit (GSPMD over "model")
     └─ shard_map  manual=("pod","data")  auto={"model"}
         ├─ per-worker grads on the local batch shard
         ├─ DGS exchange: SAMomentum -> engine top-k -> sparse collective
         │  (engine + quantize chosen by ExchangeConfig, core/engine.py)
         └─ pmean loss
     └─ params <- params - updates        (back under GSPMD)

serve/prefill steps are pure GSPMD (inference has no gradient exchange).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.distributed import (ExchangeConfig, ExchangeState, exchange)
from repro.models import config as mcfg
from repro.models import decode_step, loss_fn, prefill
from repro.models.model import abstract_params

from . import mesh as mesh_lib
from . import sharding as shard_rules


def _state_abstract(cfg: mcfg.ModelConfig, ex_cfg: ExchangeConfig,
                    params_shape, n_workers: int, shard_axes=None):
    """Abstract ExchangeState with the leading per-worker axis."""
    from repro.core.distributed import shardedps_state_size

    def vel(p):
        return jax.ShapeDtypeStruct((n_workers,) + tuple(p.shape),
                                    jnp.float32)

    velocity = jax.tree.map(vel, params_shape)
    leaves, treedef = jax.tree.flatten(params_shape)
    if shard_axes is None:
        shard_axes = [None] * len(leaves)
    if ex_cfg.mode == "shardedps":
        shards = [
            jax.ShapeDtypeStruct(
                (n_workers,
                 shardedps_state_size(tuple(l.shape), ax, n_workers)),
                jnp.float32)
            for l, ax in zip(leaves, shard_axes)
        ]
        m = jax.tree.unflatten(treedef, shards)
        v = jax.tree.unflatten(treedef, shards)
        # per-worker route-overflow counter (core/distributed.py threads it
        # through shardedps_exchange); other modes carry the empty default
        ovf = jax.ShapeDtypeStruct((n_workers,), jnp.int32)
    else:
        m = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((n_workers, 0), jnp.float32),
            params_shape)
        v = m
        ovf = ()
    return ExchangeState(velocity=velocity, m_shard=m, v_shard=v,
                         overflow=ovf)


def init_exchange_state(params, ex_cfg: ExchangeConfig, n_workers: int,
                        shard_axes=None):
    """Concrete zero state (small-scale training)."""
    abstract = _state_abstract(None, ex_cfg, params, n_workers, shard_axes)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abstract)


def zeros_state(bundle: "StepBundle"):
    """Concrete zero ExchangeState matching a train bundle's abstract spec."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        bundle.arg_specs[1])


@dataclasses.dataclass
class StepBundle:
    fn: object                    # callable to jit/lower
    in_shardings: tuple
    arg_specs: tuple              # abstract args for .lower()
    donate_argnums: tuple = ()
    out_shardings: object = None  # pins donated-state shardings across steps

    def jit(self, **kw):
        import jax as _jax
        if self.out_shardings is not None:
            kw.setdefault("out_shardings", self.out_shardings)
        return _jax.jit(self.fn, in_shardings=self.in_shardings,
                        donate_argnums=self.donate_argnums, **kw)


def build_train_step(cfg: mcfg.ModelConfig, mesh, ex_cfg: ExchangeConfig,
                     *, lr: float = 1e-2, batch_specs_abstract=None,
                     remat: bool = True) -> StepBundle:
    if ex_cfg.engine != "auto":
        from repro.core.engine import get_engine
        get_engine(ex_cfg.engine)  # fail fast at build time, not in-jit
    data_axes = mesh_lib.data_axis_names(mesh)
    W = mesh_lib.n_data_workers(mesh)
    msize = mesh_lib.model_axis_size(mesh)
    params_shape = abstract_params(cfg)
    pspecs = shard_rules.param_specs(cfg, params_shape, msize)
    hints = shard_rules.shard_axis_hints(cfg, params_shape, msize)

    def inner(params, ex_state, batch):
        ex_state = jax.tree.map(lambda x: x[0], ex_state)  # (1,...) -> (...)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, remat=remat)[0])(params)
        updates, ex_state = exchange(
            ex_state, grads, cfg=ex_cfg, lr=lr, axis_names=data_axes,
            n_workers=W, shard_axes=hints)
        loss = jax.lax.pmean(loss, data_axes)
        ex_state = jax.tree.map(lambda x: x[None], ex_state)
        return loss, updates, ex_state

    state_spec_manual = jax.tree.map(
        lambda _: P(data_axes),
        _state_abstract(cfg, ex_cfg, params_shape, W, hints))
    batch_spec_manual = jax.tree.map(
        lambda l: P(data_axes) if l.ndim else P(), batch_specs_abstract)

    def train_step(params, ex_state, batch):
        loss, updates, ex_state = jax.shard_map(
            inner, mesh=mesh, axis_names=set(data_axes),
            in_specs=(P(), state_spec_manual, batch_spec_manual),
            out_specs=(P(), P(), state_spec_manual),
            check_vma=False,
        )(params, ex_state, batch)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
            params, updates)
        return params, ex_state, loss

    param_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    vel_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*((data_axes,) + tuple(s)))), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    flat_sharding = NamedSharding(mesh, P(data_axes, None))
    state_shardings = ExchangeState(
        velocity=vel_shardings,
        m_shard=jax.tree.map(lambda _: flat_sharding, params_shape),
        v_shard=jax.tree.map(lambda _: flat_sharding, params_shape),
        overflow=(NamedSharding(mesh, P(data_axes))
                  if ex_cfg.mode == "shardedps" else ()))
    batch_shardings = jax.tree.map(
        lambda l: NamedSharding(
            mesh, P(*((data_axes,) + (None,) * (l.ndim - 1))) if l.ndim
            else P()),
        batch_specs_abstract)
    state_abstract = _state_abstract(cfg, ex_cfg, params_shape, W, hints)
    return StepBundle(
        fn=train_step,
        in_shardings=(param_shardings, state_shardings, batch_shardings),
        arg_specs=(params_shape, state_abstract, batch_specs_abstract),
        donate_argnums=(0, 1),
        out_shardings=(param_shardings, state_shardings,
                       NamedSharding(mesh, P())),
    )


def build_prefill_step(cfg: mcfg.ModelConfig, mesh, *, shape) -> StepBundle:
    from repro.configs.shapes import input_specs
    msize = mesh_lib.model_axis_size(mesh)
    data_axes = mesh_lib.data_axis_names(mesh)
    params_shape = abstract_params(cfg)
    pspecs = shard_rules.param_specs(cfg, params_shape, msize)
    specs = input_specs(cfg, shape)

    def prefill_step(params, batch):
        logits, caches, aux = prefill(
            params, batch["tokens"], cfg,
            frontend_embeds=batch.get("frontend_embeds"))
        return logits, caches

    param_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    batch_shardings = jax.tree.map(
        lambda l: NamedSharding(
            mesh, P(*((data_axes,) + (None,) * (l.ndim - 1)))),
        specs)
    return StepBundle(
        fn=prefill_step,
        in_shardings=(param_shardings, batch_shardings),
        arg_specs=(params_shape, specs),
    )


def build_serve_step(cfg: mcfg.ModelConfig, mesh, *, shape) -> StepBundle:
    from repro.configs.shapes import input_specs
    msize = mesh_lib.model_axis_size(mesh)
    data_axes = mesh_lib.data_axis_names(mesh)
    n_data = mesh_lib.n_data_workers(mesh)
    params_shape = abstract_params(cfg)
    pspecs = shard_rules.param_specs(cfg, params_shape, msize)
    specs = input_specs(cfg, shape)
    long_mode = shape.long

    def serve_step(params, caches, token, pos):
        return decode_step(params, caches, token, pos, cfg,
                           long_mode=long_mode)

    param_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    cspecs = shard_rules.cache_specs(
        cfg, specs["caches"], data_axes, msize,
        batch=shape.global_batch, n_data=n_data)
    cache_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    tok_sharding = NamedSharding(
        mesh, P(data_axes, None)
        if shape.global_batch % n_data == 0 else P())
    return StepBundle(
        fn=serve_step,
        in_shardings=(param_shardings, cache_shardings, tok_sharding,
                      NamedSharding(mesh, P())),
        arg_specs=(params_shape, specs["caches"], specs["token"],
                   specs["pos"]),
        donate_argnums=(1,),
    )


def build_step(cfg, mesh, shape, *, ex_cfg: ExchangeConfig | None = None,
               lr: float = 1e-2) -> StepBundle:
    """One entry point: pick the right step kind for the input shape."""
    from repro.configs.shapes import input_specs
    ex_cfg = ex_cfg or ExchangeConfig(mode="allgather")
    if shape.kind == "train":
        return build_train_step(cfg, mesh, ex_cfg, lr=lr,
                                batch_specs_abstract=input_specs(cfg, shape))
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape=shape)
    return build_serve_step(cfg, mesh, shape=shape)
