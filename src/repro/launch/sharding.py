"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

The "model" mesh axis carries tensor/expert parallelism (GSPMD-auto); the
("pod", "data") axes carry data parallelism (manual inside the DGS exchange
shard_map).  Rules are name+shape based so one function serves all 10
architectures:

* attn/MLP in-projections  (d, H*hd|ff)  -> P(None, "model")
* out/down projections     (ff|H*hd, d)  -> P("model", None)
* MoE expert tensors       (E, d, f)     -> P("model", None, None)  (EP)
* embeddings               (V, d)        -> P("model", None)
* vectors/norms            (d,)          -> replicated
* stacked unit params get a leading None.

``shard_axis_hints`` returns, per parameter leaf, the index of the dimension
sharded over "model" (or None).  The DGS mesh exchange uses it to run top-k
along *unsharded* dimensions only, so sparsification never forces a gather
of the gradient across the model axis (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# names of projection params whose LAST dim shards over model
_COL_SHARDED = {"wq", "wk", "wv", "up", "gate", "wq_b", "wkv_b", "in_proj"}
# names whose FIRST dim shards over model
_ROW_SHARDED = {"wo", "down", "out_proj"}


def _leaf_rule(path_keys: tuple[str, ...], shape: tuple[int, ...],
               model_size: int, n_kv_heads: int = 0) -> P:
    """PartitionSpec for one (possibly unit-stacked) parameter leaf."""
    names = [k for k in path_keys]
    stacked = names and names[0] == "units"

    def wrap(spec_dims):
        if stacked:
            return P(*([None] + spec_dims))
        return P(*spec_dims)

    core = shape[1:] if stacked else shape
    nd = len(core)
    owner = None
    for n in reversed(names):
        if n in ("w", "b", "scale", "bias", "table", "conv_w", "conv_b",
                 "A_log", "dt_bias", "D"):
            continue
        owner = n
        break
    last = names[-1]

    def ok(dim_idx):
        return core[dim_idx] % model_size == 0 and core[dim_idx] >= model_size

    # MoE expert tensors: (E, d, f) / (E, f, d): expert parallelism on dim 0
    if "moe" in names and last in ("up", "gate", "down") and nd == 3:
        if ok(0):
            return wrap(["model", None, None])
        return wrap([None] * nd)
    if last == "table" and nd == 2:          # embedding (V, d)
        if ok(0):
            return wrap(["model", None])     # vocab-parallel
        if ok(1):
            return wrap([None, "model"])
        return wrap([None, None])
    if last in ("w", "b") and owner in ("wk", "wv"):
        # K/V projections: shard only when whole KV heads land on each model
        # shard.  If n_kv_heads < model_size the shards would cut through
        # head_dim, and RoPE's strided slices on the fractured dim crash
        # XLA's SPMD gather partitioner (observed on every kv<16 arch).
        if n_kv_heads % model_size == 0 and ok(nd - 1):
            return wrap([None] * (nd - 1) + ["model"])
        return wrap([None] * nd)
    if last == "w" and owner in _COL_SHARDED and nd == 2:
        return wrap([None, "model"] if ok(1) else [None, None])
    if last == "b" and owner in _COL_SHARDED and nd == 1:
        return wrap(["model"] if ok(0) else [None])
    if last == "w" and owner in _ROW_SHARDED and nd == 2:
        return wrap(["model", None] if ok(0) else [None, None])
    if last == "w" and owner == "lm_head" and nd == 2:  # (d, V)
        return wrap([None, "model"] if ok(1) else [None, None])
    if last == "conv_w" and nd == 2:         # (K, conv_dim)
        return wrap([None, "model"] if ok(1) else [None, None])
    if last in ("conv_b",) and nd == 1:
        return wrap(["model"] if ok(0) else [None])
    if last in ("A_log", "dt_bias", "D") and nd == 1:
        return wrap(["model"] if ok(0) else [None])
    if owner == "router":
        return wrap([None] * nd)
    # norms / small vectors / anything else: replicated
    return wrap([None] * nd)


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return tuple(out)


def param_specs(cfg: ModelConfig, params_shape, model_size: int):
    """Pytree of PartitionSpec matching ``params_shape`` (shapes or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [
        _leaf_rule(_path_names(path), tuple(leaf.shape), model_size,
                   n_kv_heads=cfg.n_kv_heads)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_axis_hints(cfg: ModelConfig, params_shape, model_size: int):
    """Per-leaf index of the model-sharded dim (None if replicated)."""
    specs = param_specs(cfg, params_shape, model_size)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    hints = []
    for spec in flat_specs:
        hint = None
        for i, s in enumerate(spec):
            if s == "model":
                hint = i
                break
        hints.append(hint)
    return hints


def batch_specs(cfg: ModelConfig, batch_shape, data_axes):
    """Shard every batch input along its leading (batch) dim."""
    def rule(leaf):
        if leaf.ndim == 0:
            return P()
        return P(*([data_axes] + [None] * (leaf.ndim - 1)))

    return jax.tree.map(rule, batch_shape)


def cache_specs(cfg: ModelConfig, caches_shape, data_axes, model_size: int,
                *, batch: int, n_data: int):
    """Decode caches: (n_units, B, L, heads..., hd).

    Shard batch over the data axes when divisible; otherwise (long_500k,
    B=1) shard the cache length.  Shard the heads (or head_dim / state)
    over "model" when divisible.
    """
    shard_batch = batch % n_data == 0 and batch >= n_data

    def rule(leaf):
        dims: list = [None] * leaf.ndim
        if leaf.ndim >= 2:
            if shard_batch:
                dims[1] = data_axes
            elif leaf.ndim >= 3 and leaf.shape[2] % n_data == 0:
                dims[2] = data_axes  # shard cache length / conv dim
        # model axis: try trailing dims from the end (hd, heads, state)
        for i in range(leaf.ndim - 1, 2, -1):
            if leaf.shape[i] % model_size == 0 and leaf.shape[i] >= model_size:
                dims[i] = "model"
                break
        return P(*dims)

    return jax.tree.map(rule, caches_shape)
