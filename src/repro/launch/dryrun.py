"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, prove memory fits, and extract roofline terms.

MUST be run as a fresh process: the device-count override below has to land
before jax initializes.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch chatglm3-6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

Results are written one JSON per combination so the roofline table
(benchmarks/roofline_table.py) and EXPERIMENTS.md can be regenerated.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, get_shape       # noqa: E402
from repro.core.distributed import ExchangeConfig                  # noqa: E402
from repro.launch import mesh as mesh_lib                          # noqa: E402
from repro.launch import roofline                                  # noqa: E402
from repro.launch.steps import build_step                          # noqa: E402


def _compile_step(cfg, mesh, shape, ex_cfg):
    bundle = build_step(cfg, mesh, shape, ex_cfg=ex_cfg)
    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.arg_specs)
        return lowered.compile()


def _extrapolate_costs(cfg, mesh, shape, ex_cfg):
    """Correct for XLA cost_analysis counting scan bodies once: lower 1-unit
    and 2-unit UNROLLED variants, fit cost(T) = out + T * body, extrapolate
    to the full unit count.  Valid because per-unit structure is identical
    and the out-of-scan work (embed/head/loss) is constant in T while the
    exchange scales linearly (both fit the affine model)."""
    import dataclasses as dc

    from repro.launch.roofline import collective_stats, _WIRE_MULT
    from repro.models.model import scan_unrolled

    pattern, n_units = cfg.unit_pattern()
    plen = len(pattern)
    points = {}
    for units in (1, 2):
        sub = dc.replace(cfg, n_layers=units * plen)
        with scan_unrolled():
            compiled = _compile_step(sub, mesh, shape, ex_cfg)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        colls = collective_stats(compiled.as_text())
        wire = sum(s["wire_bytes"] for s in colls.values())
        points[units] = (float(cost.get("flops", 0.0)),
                         float(cost.get("bytes accessed", 0.0)), wire)
    f1, b1, w1 = points[1]
    f2, b2, w2 = points[2]

    def fit(v1, v2):
        body = max(v2 - v1, 0.0)
        out = max(v1 - body, 0.0)
        return out + n_units * body

    return fit(f1, f2), fit(b1, b2), fit(w1, w2)


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            ex_mode: str = "allgather", density: float = 0.01,
            out_dir: str | None = None, verbose: bool = True,
            extrapolate: bool = True, wire_dtype: str = "float32",
            bucket_factor: float = 2.0, ssd_chunk: int | None = None,
            tag_suffix: str = "") -> dict:
    import dataclasses as dc
    cfg = get_arch(arch)
    if ssd_chunk is not None and cfg.ssm is not None:
        cfg = dc.replace(cfg, ssm=dc.replace(cfg.ssm, chunk=ssd_chunk))
    if os.environ.get("REPRO_ACT_SHARD") == "1":
        cfg = dc.replace(cfg, activation_sharding=True)
    shape = get_shape(shape_name)
    multi = mesh_kind == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    n_devices = 512 if multi else 256
    if not multi:
        # single-pod mesh uses the first 256 of the 512 host devices;
        # REPRO_MESH_SHAPE=dxm relays them out (same chips, different
        # data/model split — a §Perf sharding-scheme variant)
        import numpy as np
        d, m = map(int, os.environ.get("REPRO_MESH_SHAPE", "16x16")
                   .split("x"))
        assert d * m == 256, (d, m)
        devs = np.asarray(jax.devices()[:256]).reshape(d, m)
        mesh = jax.sharding.Mesh(devs, ("data", "model"))
    ex_cfg = ExchangeConfig(mode=ex_mode, density=density,
                            wire_dtype=wire_dtype,
                            bucket_factor=bucket_factor)
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape, ex_cfg=ex_cfg)
    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    report = roofline.analyze(compiled, arch=arch, shape=shape,
                              mesh_name=mesh_kind, cfg=cfg,
                              n_devices=n_devices)
    if extrapolate:
        flops, bytes_acc, wire = _extrapolate_costs(cfg, mesh, shape, ex_cfg)
        report = roofline.RooflineReport(
            arch=report.arch, shape=report.shape, mesh=report.mesh,
            flops_per_device=flops, bytes_per_device=bytes_acc,
            wire_bytes_per_device=wire,
            collective_counts=report.collective_counts,
            compute_s=flops / roofline.PEAK_FLOPS,
            memory_s=bytes_acc / roofline.HBM_BW,
            collective_s=wire / roofline.ICI_BW,
            model_flops=report.model_flops, n_devices=report.n_devices,
            peak_bytes_per_device=report.peak_bytes_per_device)
    row = report.row()
    row.update({
        "ex_mode": ex_mode if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind} "
              f"({ex_mode if shape.kind == 'train' else shape.kind}): "
              f"OK  compile={t_compile:.1f}s")
        print(f"  memory_analysis: args="
              f"{_gb(row['argument_bytes'])} temp={_gb(row['temp_bytes'])} "
              f"out={_gb(row['output_bytes'])} (per device)")
        print(f"  cost_analysis: flops/dev={row['hlo_flops_per_device']:.3e} "
              f"bytes/dev={row['hlo_bytes_per_device']:.3e}")
        print(f"  roofline: compute={row['compute_s']*1e3:.2f}ms "
              f"memory={row['memory_s']*1e3:.2f}ms "
              f"collective={row['collective_s']*1e3:.2f}ms "
              f"-> dominant={row['dominant']}")
        print(f"  collectives: {row['collective_counts']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_kind}"
        if ex_mode != "allgather" and shape.kind == "train":
            tag += f"_{ex_mode}"
        tag += tag_suffix
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(row, f, indent=1, default=str)
    return row


def _gb(x):
    return f"{x/2**30:.2f}GiB" if x is not None else "?"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--ex-mode", default="allgather",
                    choices=["dense", "allgather", "shardedps"])
    ap.add_argument("--density", type=float, default=0.01)
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--bucket-factor", type=float, default=2.0)
    ap.add_argument("--ssd-chunk", type=int, default=None)
    ap.add_argument("--tag-suffix", default="")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = sorted(SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                try:
                    run_one(arch, shape, mesh_kind, ex_mode=args.ex_mode,
                            density=args.density, out_dir=args.out,
                            wire_dtype=args.wire_dtype,
                            bucket_factor=args.bucket_factor,
                            ssd_chunk=args.ssd_chunk,
                            tag_suffix=args.tag_suffix)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    print(f"[dryrun] {arch} x {shape} x {mesh_kind}: "
                          f"FAIL {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
