"""Roofline-term extraction from a compiled dry-run artifact.

The container is CPU-only; TPU v5e is the *target*.  We therefore derive the
three roofline terms from the compiled (SPMD-partitioned, per-device) HLO:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF bf16)
    memory term     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective term = wire_bytes_per_device / ICI_link_bw      (50 GB/s)

``cost_analysis()`` provides per-device FLOPs and bytes.  Collective bytes
are NOT in cost_analysis: we parse the optimized HLO and sum collective-op
output sizes, with op-specific wire multipliers (ring all-reduce moves ~2x
the payload; all-gather/reduce-scatter move (n-1)/n ~ 1x; all-to-all 1x).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12         # bf16 per chip, TPU v5e
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (prompt-specified constant)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# wire bytes moved per device, as a multiple of the op's output bytes
_WIRE_MULT = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather ring phases
    "all-gather": 1.0,          # receives (n-1)/n of output ~ 1
    "reduce-scatter": 1.0,      # sends (n-1)/n of input ~ output*(n-1)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind {count, out_bytes, wire_bytes} from partitioned HLO."""
    stats: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        s = stats.setdefault(kind, {"count": 0, "out_bytes": 0,
                                    "wire_bytes": 0.0})
        s["count"] += 1
        s["out_bytes"] += b
        s["wire_bytes"] += b * _WIRE_MULT[kind]
    return stats


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collective_counts: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6*N(active)*D, global
    n_devices: int
    peak_bytes_per_device: float | None = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        per_dev_model = self.model_flops / max(1, self.n_devices)
        return per_dev_model / self.flops_per_device if \
            self.flops_per_device else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_device": self.flops_per_device,
            "hlo_bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collective_counts": self.collective_counts,
            "peak_bytes_per_device": self.peak_bytes_per_device,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE), D = tokens processed; decode
    processes global_batch tokens per step; train includes backward (the 6x
    already covers fwd+bwd; for inference steps we use 2*N*D)."""
    n = cfg.param_count()
    if cfg.moe is not None:
        e = cfg.moe
        total_expert = cfg.n_layers * e.n_experts
        gates = 3 if cfg.activation in ("swiglu", "geglu") else 2
        expert_params = (cfg.n_layers * e.n_experts * gates
                         * cfg.d_model * e.d_expert)
        active = (cfg.n_layers * e.top_k * gates * cfg.d_model * e.d_expert)
        n = n - expert_params + active
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze(compiled, *, arch: str, shape, mesh_name: str, cfg,
            n_devices: int) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    colls = collective_stats(text)
    wire = sum(s["wire_bytes"] for s in colls.values())
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                    getattr(ma, "argument_size_in_bytes", 0) +
                    getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        wire_bytes_per_device=wire,
        collective_counts={k: v["count"] for k, v in colls.items()},
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=wire / ICI_BW,
        model_flops=model_flops(cfg, shape),
        n_devices=n_devices,
        peak_bytes_per_device=mem,
    )
