from repro import compat  # noqa: F401  (jax version backfills, side effects)

from . import mesh, roofline, sharding, steps

__all__ = ["mesh", "roofline", "sharding", "steps"]
