from . import mesh, roofline, sharding, steps

__all__ = ["mesh", "roofline", "sharding", "steps"]
