from repro import compat  # noqa: F401  (jax version backfills, side effects)

from . import mesh, roofline, sharding, steps

__all__ = ["cluster", "mesh", "roofline", "sharding", "steps"]


def __getattr__(name):
    # lazy: the cluster CLI pulls in repro.cluster, which most launch users
    # (mesh/serve paths) never need
    if name == "cluster":
        import importlib

        return importlib.import_module(".cluster", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
