"""Serving launcher: batched prefill + decode loop on a host mesh.

Smoke-scale demonstration of the serve path (the production decode shapes
are exercised via dryrun.py):

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.launch import mesh as mesh_lib
    from repro.models import decode_step, init_params, prefill

    cfg = get_arch(args.arch).reduced()
    n_dev = jax.device_count()
    mesh = mesh_lib.make_mesh((1, n_dev), ("data", "model"))
    print(f"[serve] arch={cfg.name} mesh={dict(mesh.shape)}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    fe = None
    if cfg.frontend_tokens:
        fe = jax.random.normal(key, (args.batch, cfg.frontend_tokens,
                                     cfg.d_model), cfg.cdtype)

    @jax.jit
    def do_prefill(params, prompt):
        return prefill(params, prompt, cfg, frontend_embeds=fe,
                       max_len=max_len)

    @jax.jit
    def do_decode(params, caches, token, pos):
        return decode_step(params, caches, token, pos, cfg)

    with mesh:
        logits, caches, _ = do_prefill(params, prompt)
        tokens = [jnp.argmax(logits[:, -1], axis=-1)]
        for t in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + t)
            logits, caches = do_decode(params, caches, tokens[-1][:, None],
                                       pos)
            if args.temperature > 0:
                k2 = jax.random.fold_in(key, t)
                nxt = jax.random.categorical(
                    k2, logits[:, 0] / args.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            tokens.append(nxt)
    out = jnp.stack(tokens, axis=1)
    print("[serve] generated token ids:")
    for b in range(args.batch):
        print("  seq", b, out[b].tolist())
    print("[serve] done")


if __name__ == "__main__":
    main()
