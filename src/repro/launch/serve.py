"""Serving launcher: live inference fleet fed by sparse model-diffs.

Three roles:

* ``--role fleet`` (default) — the serve subsystem end-to-end over real
  TCP (DESIGN.md §13): this process runs the training coordinator with
  the subscriber leg enabled; training clients AND inference replicas are
  separate OS processes.  Replicas SUBscribe, apply one coalesced
  re-sparsified ARENA diff per decode boundary (bounded staleness), and
  SYNC to the bit-exact final model at quiesce.  The coordinator also
  appends sparse delta-checkpoints of the live arena
  (checkpoint/delta.py).

      PYTHONPATH=src python -m repro.launch.serve --smoke \
          --ckpt-dir /tmp/ckpt --trace-dir /tmp/trace

  ``--smoke`` (the CI serve gate) asserts every replica's final params
  are bit-identical to the server model and that restoring the
  delta-checkpoint chain reproduces the live arena bit for bit.

* ``--role replica`` — one inference replica process: connects over TCP,
  decodes between diff pulls, writes its final arena to ``--out``.

* ``--role decode`` — the standalone mesh decode demo (prefill + decode
  loop on a host mesh; no cluster).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys
import time

from repro import telemetry

log = telemetry.get_logger("serve")


# ---------------------------------------------------------------------------
# --role replica: one TCP inference replica process
# ---------------------------------------------------------------------------

def run_replica(args) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.cluster import wire
    from repro.cluster.replica import InferenceReplica
    from repro.cluster.transport import TcpClientTransport
    from repro.launch.cluster import _problem

    params0, _, _, _ = _problem(args)
    addr = wire.SUBSCRIBER_BASE + args.replica_id
    transport = TcpClientTransport(args.host, args.port, addr,
                                   connect_timeout=args.timeout)

    # the decode workload: batched classification forward on a fixed
    # eval set — enough to exercise decode-while-training; the arena
    # swap underneath it is what we're actually demonstrating
    from repro.data.synthetic import ClassificationTask
    task = ClassificationTask(n_features=args.features,
                              n_classes=args.classes,
                              batch_size=args.batch_size,
                              noise=0.6, seed=args.seed)
    x_eval, y_eval = task.eval_set(256)
    accs = []

    @jax.jit
    def logits_fn(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    def decode_fn(params, step):
        acc = float(jnp.mean(
            jnp.argmax(logits_fn(params, x_eval), -1) == y_eval))
        accs.append(acc)

    replica = InferenceReplica(
        transport, params0, replica_id=args.replica_id,
        max_staleness=args.max_staleness, decode_fn=decode_fn,
        recv_timeout=args.timeout)
    result = replica.run()
    transport.close()
    if args.out:
        np.save(args.out, result.arena)
    s = result.stats
    log.info(f"[replica {args.replica_id}] version={result.version} "
             f"decodes={s['decodes']} diffs={s['diffs']} "
             f"pulls={s['pulls']} bytes_in={s['bytes_in']} "
             f"stale_waits={s['stale_waits']} "
             f"acc {accs[0] if accs else 0:.3f} -> "
             f"{accs[-1] if accs else 0:.3f}")
    return 0


# ---------------------------------------------------------------------------
# --role fleet: coordinator + training clients + replica fleet over TCP
# ---------------------------------------------------------------------------

def run_fleet(args) -> int:
    import numpy as np

    from repro.cluster.coordinator import Coordinator
    from repro.cluster.transport import TcpCoordinatorTransport
    from repro.core.engine import CompressionSpec
    from repro.core.paramspace import ParamSpace
    from repro.launch import cluster as cluster_launch
    from repro.launch.cluster import _problem, _shared_flags

    params0, _, _, accuracy = _problem(args)
    recorder = (telemetry.Recorder(args.trace_dir)
                if args.trace_dir else telemetry.NULL)
    if recorder.enabled:
        telemetry.set_recorder(recorder)

    transport = TcpCoordinatorTransport(args.host, args.port)
    out_dir = pathlib.Path(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    log.info(f"[fleet] coordinator on {transport.host}:{transport.port} "
             f"({args.clients} trainer(s) x {args.rounds} rounds, "
             f"{args.replicas} replica(s))")

    for c in range(args.clients):
        cluster_launch.spawn(
            [sys.executable, "-m", "repro.launch.cluster",
             "--role", "client", "--client-id", str(c),
             "--port", str(transport.port)] + _shared_flags(args))
    replica_outs = [out_dir / f"replica_{i}.npy"
                    for i in range(args.replicas)]
    for i in range(args.replicas):
        cluster_launch.spawn(
            [sys.executable, "-m", "repro.launch.serve",
             "--role", "replica", "--replica-id", str(i),
             "--port", str(transport.port),
             "--out", str(replica_outs[i]),
             "--max-staleness", str(args.max_staleness)]
            + _shared_flags(args))

    coord = Coordinator(
        transport=transport,
        params0=params0,
        n_slots=args.clients,
        secondary_density=args.secondary_density,
        secondary_spec=CompressionSpec(engine="exact",
                                       quantize=args.secondary_quantize),
        recv_timeout=args.timeout,
        recorder=recorder,
        push_density=args.push_density,
        min_subscribers=args.replicas,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    t0 = time.perf_counter()
    try:
        with recorder.span("fleet/serve"):
            final, hist = coord.serve()
        dt = time.perf_counter() - t0
    finally:
        cluster_launch.reap_children()
        transport.close()

    n = max(1, len(hist.losses))
    cnt = hist.metrics["counters"]
    log.info(f"[fleet] {len(hist.losses)} events in {dt:.1f}s | "
             f"loss {hist.losses[:3].mean():.4f} -> "
             f"{hist.losses[-3:].mean():.4f} | acc {accuracy(final):.3f}")
    for i in range(args.replicas):
        log.info(f"[fleet] replica {i}: pushes="
                 f"{cnt.get(f'sub/{i}/pushes', 0):.0f} "
                 f"push_bytes={cnt.get(f'sub/{i}/push_bytes', 0):.0f} "
                 f"lag_max={cnt.get(f'sub/{i}/lag_max', 0):.0f} "
                 f"version={cnt.get(f'sub/{i}/version', 0):.0f}")
    if args.ckpt_dir:
        log.info(f"[fleet] delta-checkpoint: "
                 f"{cnt.get('ckpt_deltas', 0):.0f} deltas, "
                 f"{cnt.get('ckpt_bytes', 0):.0f} bytes -> {args.ckpt_dir}")
    if recorder.enabled:
        telemetry.set_recorder(None)
        paths = recorder.close()
        log.info(f"[fleet] telemetry: {' '.join(paths)}")

    if args.smoke:
        space = ParamSpace.from_tree(params0)
        final_arena = np.asarray(space.pack(final))
        assert len(hist.losses) == args.clients * args.rounds, \
            "smoke: missing events"
        for i, path in enumerate(replica_outs):
            arena = np.load(path)
            assert np.array_equal(arena, final_arena), \
                f"smoke: replica {i} final != server model (bitwise)"
        if args.ckpt_dir:
            from repro.checkpoint import load_delta_checkpoint
            arena, version, _ = load_delta_checkpoint(args.ckpt_dir)
            assert np.array_equal(arena, final_arena), \
                "smoke: delta-checkpoint restore != live arena (bitwise)"
            assert version == len(hist.losses)
        log.info(f"[fleet] smoke OK: {args.replicas} replicas bit-identical"
                 f" to server"
                 + (", checkpoint restore bit-identical"
                    if args.ckpt_dir else ""))
    return 0


# ---------------------------------------------------------------------------
# --role decode: the standalone mesh decode demo
# ---------------------------------------------------------------------------

def run_decode(args) -> int:
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.launch import mesh as mesh_lib
    from repro.models import decode_step, init_params, prefill

    cfg = get_arch(args.arch).reduced()
    n_dev = jax.device_count()
    mesh = mesh_lib.make_mesh((1, n_dev), ("data", "model"))
    print(f"[serve] arch={cfg.name} mesh={dict(mesh.shape)}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    fe = None
    if cfg.frontend_tokens:
        fe = jax.random.normal(key, (args.batch, cfg.frontend_tokens,
                                     cfg.d_model), cfg.cdtype)

    @jax.jit
    def do_prefill(params, prompt):
        return prefill(params, prompt, cfg, frontend_embeds=fe,
                       max_len=max_len)

    @jax.jit
    def do_decode(params, caches, token, pos):
        return decode_step(params, caches, token, pos, cfg)

    with mesh:
        logits, caches, _ = do_prefill(params, prompt)
        tokens = [jnp.argmax(logits[:, -1], axis=-1)]
        for t in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + t)
            logits, caches = do_decode(params, caches, tokens[-1][:, None],
                                       pos)
            if args.temperature > 0:
                k2 = jax.random.fold_in(key, t)
                nxt = jax.random.categorical(
                    k2, logits[:, 0] / args.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            tokens.append(nxt)
    out = jnp.stack(tokens, axis=1)
    print("[serve] generated token ids:")
    for b in range(args.batch):
        print("  seq", b, out[b].tolist())
    print("[serve] done")
    return 0


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--role", choices=("fleet", "replica", "decode"),
                   default="fleet")
    p.add_argument("--smoke", action="store_true",
                   help="CI serve gate: tiny fleet run + bit-identity "
                        "asserts (replicas vs server, checkpoint restore "
                        "vs live arena)")
    # fleet / replica: cluster problem flags (shared with launch.cluster)
    p.add_argument("--clients", type=int, default=1)
    p.add_argument("--rounds", type=int, default=16)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--replica-id", type=int, default=0)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--strategy", default="dgs")
    p.add_argument("--density", type=float, default=0.1)
    p.add_argument("--momentum", type=float, default=0.7)
    p.add_argument("--quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--secondary-density", type=float, default=0.2)
    p.add_argument("--secondary-quantize", default="none",
                   choices=("none", "bf16", "int8", "tern"))
    p.add_argument("--push-density", type=float, default=0.25,
                   help="per-tensor top-k density of each replica push "
                        "(<= 0: ship the exact nonzero residual)")
    p.add_argument("--max-staleness", type=int, default=4,
                   help="decode boundaries an unanswered PULL may span "
                        "before the replica blocks for the diff")
    p.add_argument("--lr", type=float, default=0.1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--alpha", type=float, default=0.0)
    p.add_argument("--participation", type=float, default=1.0)
    p.add_argument("--features", type=int, default=32)
    p.add_argument("--classes", type=int, default=8)
    p.add_argument("--hidden", type=int, default=32)
    p.add_argument("--batch-size", type=int, default=32)
    p.add_argument("--timeout", type=float, default=60.0)
    p.add_argument("--out", default=None,
                   help="replica role: write the final arena here (.npy)")
    p.add_argument("--out-dir", default=".serve_fleet",
                   help="fleet role: replica final-arena output directory")
    p.add_argument("--ckpt-dir", default=None,
                   help="append sparse delta-checkpoints of the live arena "
                        "under this directory (checkpoint/delta.py)")
    p.add_argument("--ckpt-every", type=int, default=4)
    p.add_argument("--trace-dir", default=None,
                   help="write trace.json + events.jsonl (flight recorder)")
    p.add_argument("--log-level", default=None)
    p.add_argument("--log-file", default=None)
    # decode role
    p.add_argument("--arch", default="chatglm3-6b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--devices", type=int, default=4)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args(argv)
    if args.log_level:
        telemetry.set_level(args.log_level)
    if args.log_file:
        telemetry.set_log_file(args.log_file)
    if args.push_density is not None and args.push_density <= 0:
        args.push_density = None

    if args.smoke:
        args.clients, args.rounds, args.replicas = 1, 12, 2
        args.strategy, args.density = "dgs", 0.1
        args.secondary_density = 0.2

    if args.role == "replica":
        return run_replica(args)
    if args.role == "decode":
        return run_decode(args)
    from repro.launch.cluster import install_reaper
    install_reaper()
    return run_fleet(args)


if __name__ == "__main__":
    sys.exit(main())
