from .optimizers import (AdamWState, MomentumState, adamw_init, adamw_update,
                         momentum_init, momentum_update, sgd_update,
                         cosine_lr, step_decay_lr)

__all__ = [
    "AdamWState", "MomentumState", "adamw_init", "adamw_update",
    "momentum_init", "momentum_update", "sgd_update", "cosine_lr",
    "step_decay_lr",
]
