"""Optimizers (pure-pytree, no external deps) and LR schedules.

The DGS path does NOT use these for the exchanged update (SAMomentum *is*
the optimizer there — see core/samomentum.py); they serve the baselines, the
single-node MSGD reference, and the dense mesh-training path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MomentumState(NamedTuple):
    velocity: object


def momentum_init(params) -> MomentumState:
    return MomentumState(velocity=jax.tree.map(jnp.zeros_like, params))


def momentum_update(params, grads, state: MomentumState, *, lr: float,
                    momentum: float = 0.9, nesterov: bool = False):
    v = jax.tree.map(lambda u, g: momentum * u + g, state.velocity, grads)
    if nesterov:
        upd = jax.tree.map(lambda g, u: g + momentum * u, grads, v)
    else:
        upd = v
    new_params = jax.tree.map(lambda p, u: p - lr * u.astype(p.dtype),
                              params, upd)
    return new_params, MomentumState(velocity=v)


def sgd_update(params, grads, *, lr: float):
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                        params, grads)


class AdamWState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array


def adamw_init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(mu=z, nu=z, count=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state: AdamWState, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0):
    c = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(p, m, n):
        step = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=c)


def step_decay_lr(base_lr: float, *, boundaries=(0.6, 0.8), factor=0.1,
                  total_steps: int = 100):
    """The paper's schedule: decay by 0.1 at epoch 30 and 40 of 50."""
    bs = [int(b * total_steps) for b in boundaries]

    def lr_fn(step: int) -> float:
        lr = base_lr
        for b in bs:
            if step >= b:
                lr *= factor
        return lr

    return lr_fn


def cosine_lr(base_lr: float, *, warmup: int = 100, total_steps: int = 1000,
              min_frac: float = 0.1):
    def lr_fn(step: int) -> float:
        if step < warmup:
            return base_lr * (step + 1) / warmup
        t = (step - warmup) / max(1, total_steps - warmup)
        t = min(1.0, t)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * t)))

    return lr_fn
