"""JAX version compatibility backfills (installed jax is 0.4.x).

The framework is written against the current jax API surface
(``jax.shard_map`` with ``check_vma``/``axis_names``, ``jax.lax.axis_size``,
``jax.make_mesh(..., axis_types=...)``).  On jax 0.4.x those spellings do
not exist yet; this module backfills the small adapters so the same source
runs on both.  Imported for its side effects by ``repro.core`` and
``repro.launch`` (every entry point into the mesh/exchange code).
"""
from __future__ import annotations

import inspect

import jax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return

    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, axis_names=None, in_specs, out_specs,
                  check_vma: bool = True):
        """jax>=0.6 ``jax.shard_map`` spelling on the 0.4.x experimental API.

        ``axis_names`` lists the MANUAL axes; every other mesh axis is left
        to GSPMD (the 0.4.x ``auto`` frozenset, inverted).  ``check_vma``
        maps onto the old ``check_rep``.
        """
        manual = (frozenset(mesh.axis_names) if axis_names is None
                  else frozenset(axis_names))
        auto = frozenset(mesh.axis_names) - manual
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=bool(check_vma),
                          auto=auto)

    jax.shard_map = shard_map


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        """Size of a manual collective axis (psum-of-ones classic)."""
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


def jax_version() -> tuple:
    """(major, minor) of the installed jax."""
    return tuple(int(x) for x in jax.__version__.split(".")[:2])


def supports_partial_auto_shard_map() -> bool:
    """Whether shard_map with mixed manual + auto axes (auto axis size > 1)
    works.  On jax 0.4.x it crashes the XLA SPMD partitioner
    (hlo_sharding_util IsManualSubgroup check); callers fall back to
    model_par=1 there."""
    return jax_version() >= (0, 5)


def make_mesh_kwargs(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh`` marking all axes GSPMD-auto, on jax
    versions that support ``axis_types`` — empty dict otherwise (0.4.x has
    neither the kwarg nor ``jax.sharding.AxisType``)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


_install_shard_map()
_install_axis_size()
