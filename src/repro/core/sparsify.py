"""Top-k gradient sparsification primitives (static-shape, jit-safe).

The paper selects "the top (100-R)% of |v|" per parameter tensor (Algorithm 1
line 8: ``thr <- R% of |v[j]|``).  XLA requires static shapes, so we express
the same operator as a static ``k = max(1, round(density * size))`` per tensor
and exchange fixed-size ``(values, indices)`` pairs — the static-shape COO of
DESIGN.md §3.

Two selection primitives live here and are composed into the pluggable
engines of ``core/engine.py`` (DESIGN.md §10 Compression-engine) — call sites
should go through the engine layer rather than these directly:

* ``topk_select`` — exact ``lax.top_k`` over |x| (the ``exact`` engine and
  the reference oracles).
* ``sampled_threshold`` — DGC-style sampled threshold estimation (the
  ``sampled`` engine's estimator) for very large tensors, where an exact
  top-k of a 100M-element gradient would dominate step time.  The live
  selection against the estimate is ``engine._threshold_compact_rows``
  (sort-free compaction + candidate top-k); ``threshold_select`` here is
  the magnitude-keyed *reference* selector for threshold-based selection
  (full-width keyed top_k, support provably identical to exact top-k) kept
  as the semantics oracle it is tested against.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SparseLeaf(NamedTuple):
    """Fixed-size sparse representation of one flattened tensor."""

    values: jax.Array   # (k,) same dtype as source
    indices: jax.Array  # (k,) int32 into the flattened tensor
    size: int           # static: number of elements in the dense tensor

    @property
    def k(self) -> int:
        return self.values.shape[-1]


def density_to_k(size: int, density: float) -> int:
    """Static number of kept elements for a tensor of ``size`` elements."""
    if not (0.0 < density <= 1.0):
        raise ValueError(f"density must be in (0, 1], got {density}")
    return max(1, min(size, int(round(size * density))))


def topk_select(x: jax.Array, k: int) -> SparseLeaf:
    """Exact top-k by magnitude over the flattened tensor."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    return SparseLeaf(values=flat[idx], indices=idx, size=flat.shape[0])


def topk_threshold(x: jax.Array, k: int) -> jax.Array:
    """The k-th largest |x| (elements with |x| >= thr are the top-k)."""
    vals = jax.lax.top_k(jnp.abs(x.reshape(-1)), k)[0]
    return vals[-1]


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask selecting exactly the top-k |x| positions (ties broken by
    index order, matching ``lax.top_k``)."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros(flat.shape, dtype=bool).at[idx].set(True)
    return mask.reshape(x.shape)


def sparse_to_dense(leaf: SparseLeaf) -> jax.Array:
    """Decode a SparseLeaf back into a flat dense vector (scatter).

    Duplicate indices ACCUMULATE (matching the server's receive path): the
    sampled engine pads underfull messages with zero-valued duplicates of
    an already-shipped index, which must decode as a no-op — a ``.set``
    scatter would nondeterministically overwrite the real value.
    """
    out = jnp.zeros((leaf.size,), dtype=leaf.values.dtype)
    return out.at[leaf.indices].add(leaf.values)


def sparse_accumulate(dense_flat: jax.Array, leaf: SparseLeaf) -> jax.Array:
    """dense += decode(leaf) without materialising the decode."""
    return dense_flat.at[leaf.indices].add(leaf.values)


def sampled_threshold(
    x: jax.Array,
    density: float,
    *,
    sample_size: int = 65536,
    key: jax.Array | None = None,
) -> jax.Array:
    """Estimate the top-``density`` magnitude threshold from a subsample.

    Deep Gradient Compression (Lin et al. 2017) samples 0.1–1% of the tensor,
    takes the top-k of the sample, and uses that as the threshold for the full
    tensor.  We use a strided deterministic sample by default (reproducible
    under jit without threading PRNG keys through the optimizer), or a uniform
    random sample when ``key`` is given.
    """
    flat = jnp.abs(x.reshape(-1))
    n = flat.shape[0]
    s = min(sample_size, n)
    if key is None:
        # ceil stride: the sample spans the WHOLE tensor (a floor stride
        # truncates coverage to the first s*stride elements whenever n/s is
        # fractional), at the cost of ceil(n/stride) <= s actual samples
        stride = -(-n // s)
        sample = flat[::stride]
    else:
        idx = jax.random.randint(key, (s,), 0, n)
        sample = flat[idx]
    ks = max(1, int(round(sample.shape[0] * density)))
    return jax.lax.top_k(sample, ks)[0][-1]


def threshold_select(x: jax.Array, thr: jax.Array, k: int) -> SparseLeaf:
    """Select up to k elements with |x| >= thr, padded/truncated to exactly k.

    Selection is done with a single ``top_k`` over a *keyed* magnitude so that
    above-threshold elements always beat below-threshold ones; the result is
    exactly the top-k by magnitude whenever >= k elements pass the threshold,
    and otherwise the passing elements padded with the next-largest ones.
    (Identical support to exact top-k; the threshold only exists so callers
    can skip the full-tensor sort on TPU — see kernels/block_topk.py.)
    """
    flat = x.reshape(-1)
    mag = jnp.abs(flat)
    keyed = jnp.where(mag >= thr, mag + 1.0, mag)  # lift passing elems
    _, idx = jax.lax.top_k(keyed, k)
    idx = idx.astype(jnp.int32)
    return SparseLeaf(values=flat[idx], indices=idx, size=flat.shape[0])


# ---------------------------------------------------------------------------
# Pytree helpers: the paper loops "for j = 0..J" over parameter tensors.
# ---------------------------------------------------------------------------

def tree_ks(tree, density: float) -> list[int]:
    """Static per-leaf k for a pytree (order = jax.tree.leaves order)."""
    return [density_to_k(int(l.size), density) for l in jax.tree.leaves(tree)]


def tree_sparsify(tree, density: float):
    """Per-leaf exact top-k sparsification.

    Returns (messages, residual_tree): messages is a list of SparseLeaf (one
    per leaf, leaves order), residual_tree keeps the unsent mass (Algorithm 1
    lines 10-11).
    """
    leaves, treedef = jax.tree.flatten(tree)
    msgs, residuals = [], []
    for leaf in leaves:
        k = density_to_k(int(leaf.size), density)
        flat = leaf.reshape(-1)
        msg = topk_select(flat, k)
        resid = flat.at[msg.indices].set(0.0).reshape(leaf.shape)
        msgs.append(msg)
        residuals.append(resid)
    return msgs, jax.tree.unflatten(treedef, residuals)


def tree_desparsify(msgs, tree_like):
    """Decode a list of SparseLeaf back into a dense pytree shaped like
    ``tree_like``."""
    leaves, treedef = jax.tree.flatten(tree_like)
    dense = [
        sparse_to_dense(m).reshape(l.shape).astype(l.dtype)
        for m, l in zip(msgs, leaves)
    ]
    return jax.tree.unflatten(treedef, dense)


def message_bytes(msgs, *, index_bytes: int = 4) -> int:
    """Nominal wire size of a sparse message (values + indices).

    Accepts one arena SparseLeaf or a list of per-leaf messages.  This is
    the analytic f32+int accounting used by microbenches; the cluster
    codec's measured framing lives in ``repro.cluster.wire``.
    """
    if isinstance(msgs, SparseLeaf):
        msgs = [msgs]
    total = 0
    for m in msgs:
        total += m.values.size * m.values.dtype.itemsize
        total += m.indices.size * index_bytes
    return total


def dense_bytes(tree) -> int:
    return sum(int(l.size) * l.dtype.itemsize for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Wire quantization of sparse values — the paper's stated future work
# ("the combination of DGS and other compression approaches (e.g. TernGrad)
# can be considered", §Conclusion).  Quantization composes with DGS because
# the unsent mass still lives in the SAMomentum velocity: quantization error
# on sent values is NOT fed back (matching TernGrad's unbiased design), but
# the selection itself is error-compensated by construction.
# ---------------------------------------------------------------------------

QUANTIZE_BITS = {"none": 32, "bf16": 16, "int8": 8, "tern": 2}


@partial(jax.jit, static_argnames=("mode",))
def quantize_parts(values: jax.Array, mode: str):
    """(codes, scale, dequantized) — THE quantization arithmetic.

    The single implementation behind both :func:`quantize_dequantize`
    (every engine/strategy path) and the cluster wire codec's encoder
    (``cluster/wire.py`` ships ``codes``+``scale``, the receiver decodes to
    exactly ``dequantized``).  One jitted program means the simulator and a
    real cluster run quantize bit-identically.

    modes:
      none  — float32 passthrough (32 bits); codes == values
      bf16  — bfloat16 wire (16); codes are the bf16 values
      int8  — symmetric per-message int8 (8 + one f32 scale per message)
      tern  — TernGrad-style {-1, 0, +1} * mean|v| (2 bits + one scale);
              with top-k inputs the 0 level is unused, so this is
              effectively 1-bit sign + shared magnitude.
    """
    values = values.astype(jnp.float32)
    zero = jnp.zeros((), jnp.float32)
    if mode == "none":
        return values, zero, values
    if mode == "bf16":
        b = values.astype(jnp.bfloat16)
        return b, zero, b.astype(jnp.float32)
    if mode == "int8":
        scale = jnp.max(jnp.abs(values)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(values / scale), -127, 127)
        return q.astype(jnp.int8), scale.astype(jnp.float32), \
            (q * scale).astype(jnp.float32)
    if mode == "tern":
        # scale over NONZERO entries only: exact zeros are either genuine
        # (nothing to ship) or the sampled engine's decode-neutral padding,
        # and averaging them in would dilute the shared magnitude of every
        # real value with no error compensation; sign(0) keeps them 0
        nnz = jnp.maximum(jnp.sum(values != 0.0), 1)
        scale = jnp.sum(jnp.abs(values)) / nnz
        s = jnp.sign(values)
        return s.astype(jnp.int8), scale.astype(jnp.float32), \
            (s * scale).astype(jnp.float32)
    raise ValueError(f"unknown quantization mode {mode!r}")


def quantize_dequantize(values: jax.Array, mode: str):
    """Quantize sparse message values for the wire; returns (dequantized
    values, bits per value).  See :func:`quantize_parts` for the modes."""
    return quantize_parts(values, mode)[2], QUANTIZE_BITS[mode]


def quantize_segments(values: jax.Array, mode: str, seg) -> jax.Array:
    """Segment-wise wire quantization of a concatenated value vector.

    ``seg`` is the static per-segment length tuple (one segment per original
    parameter tensor of an arena message).  Each segment is quantized
    INDEPENDENTLY through the same jitted :func:`quantize_parts` program the
    codec's encoder uses — one scale per tensor, exactly like the per-leaf
    message path, so arena messages are bit-equal to per-leaf ones.
    """
    if mode == "none":
        return values
    if len(seg) == 1:
        return quantize_parts(values, mode)[2]
    parts, off = [], 0
    for s in seg:
        parts.append(quantize_parts(
            jax.lax.slice_in_dim(values, off, off + s), mode)[2])
        off += s
    return jnp.concatenate(parts)
