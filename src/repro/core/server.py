"""Model-difference-tracking parameter server (paper §4, Algorithm 2).

The server never stores the global model. It stores

* ``M``   — the accumulated update,  M_t = theta_t - theta_0   (Eq. 2)
* ``v_k`` — per worker k, the accumulation of everything already shipped to
            worker k.  Invariant (Eq. 4): after serving worker k at time t,
            v_k == M_t (without secondary compression).

Upward:   M <- M - decode(g_k)                      (Alg. 2 line 3; the worker
          message already contains the learning rate, see samomentum.py)
Downward: G_k <- M - v_k ;  v_k <- v_k + G_k        (Eq. 3/4)
          with optional secondary compression        (Eq. 6a/6b):
          G_k <- sparse(M - v_k) ; v_k <- v_k + G_k  (remainder implicitly
          accumulates in (M - v_k) and ships once large enough)

Everything is stored per-leaf as flat f32 vectors so the same code path
serves every architecture's parameter pytree.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from .engine import CompressionSpec
from .sparsify import (
    SparseLeaf,
    density_to_k,
    sparse_accumulate,
)


class ServerState(NamedTuple):
    M: tuple          # tuple of flat (size,) arrays, one per param leaf
    v: tuple          # tuple of (n_workers, size) arrays
    t: jax.Array      # scalar int32 update timestamp


def init(params, n_workers: int) -> ServerState:
    leaves = [l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(params)]
    M = tuple(jnp.zeros_like(l) for l in leaves)
    v = tuple(jnp.zeros((n_workers, l.shape[0]), l.dtype) for l in leaves)
    return ServerState(M=M, v=v, t=jnp.zeros((), jnp.int32))


def receive(state: ServerState, msg) -> ServerState:
    """Apply one worker's (sparse or dense) update message to M."""
    new_M = []
    for M_leaf, m in zip(state.M, msg):
        if isinstance(m, SparseLeaf):
            new_M.append(M_leaf.at[m.indices].add(-m.values))
        else:  # dense flat array (ASGD)
            new_M.append(M_leaf - m)
    return ServerState(M=tuple(new_M), v=state.v, t=state.t + 1)


def send_select(
    state: ServerState,
    worker_id,
    *,
    secondary_density: float | None = None,
    spec: CompressionSpec = engine_lib.EXACT_SPEC,
):
    """Select the RAW (unquantized) downward message G_k; no state change.

    Splitting selection from the ``v_k`` update lets the cluster runtime
    interpose the wire codec: the codec quantizes values during encode and
    :func:`send_commit` is then fed exactly what the client decoded, so
    server bookkeeping always tracks the shipped bits.
    """
    spec_raw = dataclasses.replace(spec, quantize="none")
    G = []
    for M_leaf, v_leaf in zip(state.M, state.v):
        diff = M_leaf - v_leaf[worker_id]
        if secondary_density is None:
            G.append(diff)
        else:
            k = density_to_k(int(diff.shape[0]), secondary_density)
            G.append(engine_lib.select(diff, k, spec_raw))
    return G


def send_commit(state: ServerState, worker_id, G) -> ServerState:
    """Account the SHIPPED message into v_k (Eq. 4).

    ``G`` must be what the worker actually receives — after any wire
    quantization.  Dense leaves mean "everything": v_k snaps to M exactly
    (``v + (M - v)`` would lose bits to f32 cancellation).
    """
    new_v = []
    for M_leaf, v_leaf, g in zip(state.M, state.v, G):
        if isinstance(g, SparseLeaf):
            new_v.append(v_leaf.at[worker_id].set(
                sparse_accumulate(v_leaf[worker_id], g)))
        else:
            new_v.append(v_leaf.at[worker_id].set(M_leaf))
    return ServerState(M=tuple(state.M), v=tuple(new_v), t=state.t)


def send(
    state: ServerState,
    worker_id,
    *,
    secondary_density: float | None = None,
    spec: CompressionSpec = engine_lib.EXACT_SPEC,
):
    """Produce the model-difference message G_k for ``worker_id``.

    Returns (new_state, G) where G is a list of dense flat arrays (no
    secondary compression — G is *implicitly* sparse, we account its true nnz
    for communication metrics) or a list of SparseLeaf (secondary
    compression, Alg. 2 lines 5-11, selected through the compression engine
    named by ``spec``).  Composition of :func:`send_select` + in-spec wire
    quantization + :func:`send_commit`.
    """
    G_raw = send_select(state, worker_id,
                        secondary_density=secondary_density, spec=spec)
    G = [engine_lib.quantize_leaf(g, spec.quantize)
         if isinstance(g, SparseLeaf) else g for g in G_raw]
    return send_commit(state, worker_id, G), G


def add_worker(state: ServerState) -> tuple[ServerState, int]:
    """Grow every v leaf by one zero row (elastic join); returns the slot.

    A fresh slot has v_k = 0, so a joining client starting from theta_0 is
    brought fully up to date by its first downward message (G = M - 0).
    """
    new_id = int(state.v[0].shape[0])
    new_v = tuple(
        jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)])
        for v in state.v)
    return ServerState(M=state.M, v=new_v, t=state.t), new_id


def reset_worker(state: ServerState, worker_id: int) -> ServerState:
    """Zero a departed worker's v row so the slot can serve a new client
    (which starts from theta_0 and must receive all of M on first send)."""
    new_v = tuple(v.at[worker_id].set(0.0) for v in state.v)
    return ServerState(M=state.M, v=new_v, t=state.t)


def apply_to_params(params, G):
    """Worker-side model update  theta <- theta + G  (Eq. 5)."""
    leaves, treedef = jax.tree.flatten(params)
    out = []
    for p, g in zip(leaves, G):
        if isinstance(g, SparseLeaf):
            flat = p.reshape(-1)
            flat = flat.at[g.indices].add(g.values.astype(p.dtype))
            out.append(flat.reshape(p.shape))
        else:
            out.append((p.reshape(-1) + g.astype(p.dtype)).reshape(p.shape))
    return jax.tree.unflatten(treedef, out)


def global_model(params0, state: ServerState):
    """theta_t = theta_0 + M_t (Eq. 2) — used by tests and evaluation."""
    leaves, treedef = jax.tree.flatten(params0)
    out = [
        (p.reshape(-1) + M.astype(p.dtype)).reshape(p.shape)
        for p, M in zip(leaves, state.M)
    ]
    return jax.tree.unflatten(treedef, out)


def message_nnz(G) -> int:
    """True non-zero count of a downward message (comm accounting)."""
    total = 0
    for g in G:
        if isinstance(g, SparseLeaf):
            total += int(g.values.shape[0])
        else:
            total += int(jnp.sum(g != 0.0))
    return total
