"""Model-difference-tracking parameter server (paper §4, Algorithm 2).

The server never stores the global model. It stores

* ``M``   — the accumulated update,  M_t = theta_t - theta_0   (Eq. 2)
* ``v_k`` — per worker k, the accumulation of everything already shipped to
            worker k.  Invariant (Eq. 4): after serving worker k at time t,
            v_k == M_t (without secondary compression).

Upward:   M <- M - decode(g_k)                      (Alg. 2 line 3; the worker
          message already contains the learning rate, see samomentum.py)
Downward: G_k <- M - v_k ;  v_k <- v_k + G_k        (Eq. 3/4)
          with optional secondary compression        (Eq. 6a/6b):
          G_k <- sparse(M - v_k) ; v_k <- v_k + G_k  (remainder implicitly
          accumulates in (M - v_k) and ships once large enough)

State lives in the FLAT PARAMETER ARENA (core/paramspace.py, DESIGN.md §8):
``M`` is one contiguous ``(total,)`` f32 buffer and ``v`` one
``(n_workers, total)`` buffer; messages are a single global-index
:class:`~repro.core.sparsify.SparseLeaf` over the arena (or one dense
``(total,)`` vector).  Receive, commit, and worker apply are therefore ONE
fused scatter-add each (``kernels.ops.scatter_add`` — the Pallas blocked
kernel on TPU) instead of one small scatter per tensor per event.
Secondary *selection* stays paper-faithful per-tensor top-k: the arena is
offset-sliced back into leaf views, each selected through the engine
registry, and the indices rebased by leaf offset (``ParamSpace.select``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from .engine import CompressionSpec
from .paramspace import ParamSpace, ShardSpec
from .sparsify import SparseLeaf


def _scatter_add(dense: jax.Array, idx: jax.Array, vals: jax.Array):
    from repro.kernels import ops
    return ops.scatter_add(dense, idx, vals)


def _scatter_add_row(dense2d, row, idx, vals):
    from repro.kernels import ops
    return ops.scatter_add_row(dense2d, row, idx, vals)


class ServerState(NamedTuple):
    M: jax.Array        # (total,) f32 arena
    v: jax.Array        # (n_workers, total) f32
    t: jax.Array        # scalar int32 update timestamp
    space: ParamSpace   # static arena descriptor (registered-static pytree)


def init(params, n_workers: int) -> ServerState:
    space = ParamSpace.from_tree(params)
    return ServerState(M=jnp.zeros((space.total,), jnp.float32),
                       v=jnp.zeros((n_workers, space.total), jnp.float32),
                       t=jnp.zeros((), jnp.int32),
                       space=space)


def receive(state: ServerState, msg) -> ServerState:
    """Apply one worker's (sparse or dense) arena update message to M."""
    if isinstance(msg, SparseLeaf):
        new_M = _scatter_add(state.M, msg.indices, -msg.values)
    else:  # dense flat arena (ASGD)
        new_M = state.M - msg
    return state._replace(M=new_M, t=state.t + 1)


def send_select(
    state: ServerState,
    worker_id,
    *,
    secondary_density: float | None = None,
    spec: CompressionSpec = engine_lib.EXACT_SPEC,
):
    """Select the RAW (unquantized) downward message G_k; no state change.

    Splitting selection from the ``v_k`` update lets the cluster runtime
    interpose the wire codec: the codec quantizes values during encode and
    :func:`send_commit` is then fed exactly what the client decoded, so
    server bookkeeping always tracks the shipped bits.
    """
    diff = state.M - state.v[worker_id]
    if secondary_density is None:
        return diff
    spec_raw = dataclasses.replace(spec, quantize="none")
    return state.space.select(diff, state.space.ks(secondary_density),
                              spec_raw)


def send_commit(state: ServerState, worker_id, G) -> ServerState:
    """Account the SHIPPED message into v_k (Eq. 4).

    ``G`` must be what the worker actually receives — after any wire
    quantization.  A dense G means "everything": v_k snaps to M exactly
    (``v + (M - v)`` would lose bits to f32 cancellation).
    """
    if isinstance(G, SparseLeaf):
        new_v = _scatter_add_row(state.v, worker_id, G.indices, G.values)
    else:
        new_v = state.v.at[worker_id].set(state.M)
    return state._replace(v=new_v)


def send_commit_rows(state: ServerState, worker_ids, G,
                     M_rows=None) -> ServerState:
    """Account a whole batch of SHIPPED messages into their ``v`` rows.

    The batched event loop's commit stage (Eq. 4, one event per batch
    lane).  ``worker_ids`` must be pairwise distinct — the scheduler's
    batching rule (``async_sim.batch_schedule``) guarantees it — so the
    rows are disjoint and ONE fused multi-row scatter
    (``kernels.ops.scatter_add_rows``) is bit-equal to committing the
    events one :func:`send_commit` at a time in any order.

    ``G`` is the stacked shipped batch: one SparseLeaf with ``(B, k)``
    values/indices, or a dense ``(B, total)`` stack.  Dense commits snap
    each row to the server's M *as of that event* (the same
    cancellation-avoiding rule as :func:`send_commit`), which is the
    ``M_rows[i]`` prefix state the batched receive scan captured — not
    the post-batch M.
    """
    if isinstance(G, SparseLeaf):
        from repro.kernels import ops
        new_v = ops.scatter_add_rows(state.v, worker_ids, G.indices,
                                     G.values)
    else:
        if M_rows is None:
            raise ValueError("dense batched commit needs the per-event "
                             "prefix M_rows (see batched_server_step_fn)")
        new_v = state.v.at[worker_ids].set(M_rows)
    return state._replace(v=new_v)


def send(
    state: ServerState,
    worker_id,
    *,
    secondary_density: float | None = None,
    spec: CompressionSpec = engine_lib.EXACT_SPEC,
):
    """Produce the model-difference message G_k for ``worker_id``.

    Returns (new_state, G) where G is one dense ``(total,)`` arena vector
    (no secondary compression — G is *implicitly* sparse, its true nnz is
    accounted for communication metrics) or one global-index SparseLeaf
    (secondary compression, Alg. 2 lines 5-11, per-tensor selection through
    the engine named by ``spec``).  Composition of :func:`send_select` +
    in-spec wire quantization + :func:`send_commit`.
    """
    G = send_select(state, worker_id,
                    secondary_density=secondary_density, spec=spec)
    if isinstance(G, SparseLeaf):
        G = engine_lib.quantize_arena(G, spec.quantize,
                                      state.space.ks(secondary_density))
    return send_commit(state, worker_id, G), G


def add_worker(state: ServerState) -> tuple[ServerState, int]:
    """Grow v by one zero row (elastic join); returns the new slot id.

    A fresh slot has v_k = 0, so a joining client starting from theta_0 is
    brought fully up to date by its first downward message (G = M - 0).
    """
    new_id = int(state.v.shape[0])
    new_v = jnp.concatenate(
        [state.v, jnp.zeros((1,) + state.v.shape[1:], state.v.dtype)])
    return state._replace(v=new_v), new_id


def reset_worker(state: ServerState, worker_id: int) -> ServerState:
    """Zero a departed worker's v row so the slot can serve a new client
    (which starts from theta_0 and must receive all of M on first send)."""
    return state._replace(v=state.v.at[worker_id].set(0.0))


def apply_update(theta: jax.Array, G) -> jax.Array:
    """Worker-side arena update  theta <- theta + G  (Eq. 5) — ONE scatter."""
    if isinstance(G, SparseLeaf):
        return _scatter_add(theta, G.indices, G.values)
    return theta + G.astype(theta.dtype)


def apply_to_params(params, G):
    """Pytree convenience wrapper around :func:`apply_update`."""
    space = ParamSpace.from_tree(params)
    return space.unpack(apply_update(space.pack(params), G))


def global_model(params0, state):
    """theta_t = theta_0 + M_t (Eq. 2) — used by tests and evaluation.

    Accepts the flat :class:`ServerState` or the stacked
    :class:`MeshServerState` (whose padded M concatenates back to the same
    global arena bit-for-bit)."""
    space = state.space
    M = mesh_arena(state) if isinstance(state, MeshServerState) else state.M
    return space.unpack(space.pack(params0) + M)


def message_nnz(G) -> int:
    """True non-zero count of a downward message (comm accounting)."""
    if isinstance(G, SparseLeaf):
        return int(G.values.shape[0])
    return int(jnp.sum(G != 0.0))


# ---------------------------------------------------------------------------
# Sharded parameter server (DESIGN.md §12).  A shard is NOT a new state
# type: it is a plain ServerState over the sub-arena of the tensors a
# leaf-aligned ShardSpec assigns to it.  Every per-shard stage is therefore
# literally the fused single-scatter op above, and because shard index
# ranges are disjoint, running the shards independently reproduces the
# single-server arithmetic bit-for-bit (scatter-adds over disjoint ranges
# commute) while per-shard M/v memory and commit work scale down with S.
# ---------------------------------------------------------------------------

def shard_params(params, shard_spec: ShardSpec) -> list[list]:
    """Per-shard leaf lists of a parameter pytree (leaf-aligned spec)."""
    leaves = jax.tree.leaves(params)
    return [shard_spec.shard_leaves(leaves, s)
            for s in range(shard_spec.n_shards)]


def init_shards(params, n_workers: int, n_shards: int,
                shard_spec: ShardSpec | None = None,
                ) -> tuple[ShardSpec, tuple[ServerState, ...]]:
    """Range-partition the arena into ``n_shards`` independent servers.

    Returns ``(shard_spec, states)`` where ``states[s]`` is a regular
    :class:`ServerState` whose arena is shard ``s``'s contiguous index
    range ``[bounds[s], bounds[s+1])`` of the global arena — M, v, and
    every derived buffer are per-shard slices.
    """
    space = ParamSpace.from_tree(params)
    if shard_spec is None:
        shard_spec = ShardSpec.for_space(space, n_shards)
    if shard_spec.leaf_splits is None:
        raise ValueError("the sharded server needs a leaf-aligned "
                         "ShardSpec (ShardSpec.for_space)")
    states = tuple(init(part, n_workers)
                   for part in shard_params(params, shard_spec))
    return shard_spec, states


def global_model_shards(params0, states) -> "object":
    """theta_t from per-shard states: shard M slices concatenate (shard
    order == leaf order for a leaf-aligned spec) back into the global
    arena — bit-equal to the single-server :func:`global_model`."""
    space = ParamSpace.from_tree(params0)
    M = jnp.concatenate([st.M for st in states if st.space.total])
    return space.unpack(space.pack(params0) + M)


# ---------------------------------------------------------------------------
# Device-mesh sharded server (DESIGN.md §14).  Instead of S host threads
# each owning a ServerState slice (above), ALL shard arenas live in one
# stacked (S, width) / (n_workers, S, width) pair so one jitted stage runs
# every shard server at once — a `shards` mesh axis places the stacks
# across devices, and global-index messages reach their owner shard via
# the in-graph alltoallv route (`distributed.shard_exchange_batch`).
# Rows are padded to a common width and masked at the true shard bounds:
# padding columns hold zeros, are never routed to (local indices are
# < sizes[s] by construction), and are sliced away by `mesh_concat` —
# so ragged and empty shards stay legal and the arithmetic is bit-equal
# to the flat server.
# ---------------------------------------------------------------------------

class MeshServerState(NamedTuple):
    M: jax.Array        # (S, width) f32, row s = shard s's arena, padded
    v: jax.Array        # (n_workers, S, width) f32
    t: jax.Array        # scalar int32 update timestamp
    overflow: jax.Array  # scalar int32 route-capacity drops (0 with the
                         # default cap — see shard_exchange_batch)
    space: ParamSpace   # static GLOBAL arena descriptor
    spec: ShardSpec     # static range partition (registered-static)


def mesh_width(spec: ShardSpec) -> int:
    """Common padded row width: ``even_stride`` unless a leaf-aligned
    shard is bigger (``for_space`` keeps tensors whole, so a shard may
    exceed the even stride)."""
    return max([ShardSpec.even_stride(spec.total, spec.n_shards),
                *spec.sizes])


def init_mesh_shards(params, n_workers: int, n_shards: int,
                     shard_spec: ShardSpec | None = None) -> MeshServerState:
    """Stacked mesh twin of :func:`init_shards` — one state, all shards."""
    space = ParamSpace.from_tree(params)
    if shard_spec is None:
        shard_spec = ShardSpec.for_space(space, n_shards)
    if shard_spec.leaf_splits is None:
        raise ValueError("the mesh-sharded server needs a leaf-aligned "
                         "ShardSpec (ShardSpec.for_space)")
    if shard_spec.total != space.total:
        raise ValueError("shard_spec does not cover the parameter arena")
    w = mesh_width(shard_spec)
    S = shard_spec.n_shards
    return MeshServerState(
        M=jnp.zeros((S, w), jnp.float32),
        v=jnp.zeros((n_workers, S, w), jnp.float32),
        t=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
        space=space,
        spec=shard_spec)


def mesh_split(spec: ShardSpec, x, width: int | None = None) -> jax.Array:
    """Cut one global ``(total,)`` arena vector into the padded ``(S,
    width)`` stack (static slices; padding columns zero)."""
    width = mesh_width(spec) if width is None else width
    rows = [jnp.pad(x[a:b], (0, width - (b - a)))
            for a, b in zip(spec.bounds[:-1], spec.bounds[1:])]
    return jnp.stack(rows)


def mesh_concat(spec: ShardSpec, xs) -> jax.Array:
    """Undo :func:`mesh_split`: mask each row at its true shard bound and
    concatenate (shard order == leaf order) back to ``(total,)``."""
    parts = [xs[s, :sz] for s, sz in enumerate(spec.sizes) if sz]
    if not parts:
        return jnp.zeros((0,), xs.dtype)
    return jnp.concatenate(parts)


def mesh_arena(state: MeshServerState) -> jax.Array:
    """The global M arena of a mesh state (checkpoints / serving / eval)."""
    return mesh_concat(state.spec, state.M)
