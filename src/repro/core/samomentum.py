"""Sparsification-Aware Momentum (SAMomentum) — paper Eq. (11)/(12), Alg. 3.

Per parameter tensor, each step:

    u      <- m * u_prev + eta * grad          (velocity accumulation)
    thr    <- k-th largest |u|                 (static-k form of "R% of |u|")
    mask   <- |u| >  thr-equivalent top-k support
    g_sent <- u . mask                         (shipped to the server, WITH lr)
    u      <- where(mask, u, u / m)            (Alg.3 line 11:
                                                u += (1/m - 1) * u . !mask)

Sent coordinates keep their velocity (momentum survives the send); unsent
coordinates are pre-divided by m so that next step's ``m * u`` decay cancels,
which telescopes (Eq. 13) into

    u_{c+T} = m * u_c + eta * sum_{i=1..T} grad_{c+i}

i.e. vanilla momentum with the batch size adaptively enlarged T x per
coordinate — the paper's equivalence theorem, property-tested in
tests/test_samomentum.py.

No residual buffer exists (contrast DGC): the velocity itself carries the
unsent mass. This halves optimizer memory vs momentum-corrected DGC.

The accumulate/select/rescale operator itself lives in core/engine.py (one
implementation behind every DGS path); this module is the pytree-shaped
optimizer face of it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import engine
from .engine import CompressionSpec
from .paramspace import ParamSpace


class SAMomentumState(NamedTuple):
    velocity: jax.Array  # (total,) f32 velocity arena (paramspace layout)


def init(params) -> SAMomentumState:
    space = ParamSpace.from_tree(params)
    return SAMomentumState(velocity=jnp.zeros((space.total,), jnp.float32))


def leaf_update(
    u_prev: jax.Array,
    grad: jax.Array,
    *,
    momentum: float,
    lr: float,
    k: int,
    spec: CompressionSpec = engine.EXACT_SPEC,
):
    """Single-tensor SAMomentum step. Returns (msg: SparseLeaf, u_new)."""
    return engine.samomentum_step(
        u_prev, grad, momentum=momentum, lr=lr, k=k, spec=spec)


def leaf_update_dense(u_prev, grad, *, momentum, lr):
    """Degenerate density=1 case: every coordinate is sent each step, so
    SAMomentum is exactly heavy-ball momentum (paper Eq. 7/8)."""
    u = engine.velocity_accumulate(u_prev, grad, momentum=momentum, lr=lr)
    return u, u


def tree_update(
    state: SAMomentumState,
    grads,
    *,
    momentum: float,
    lr: float,
    density: float,
    spec: CompressionSpec = engine.EXACT_SPEC,
):
    """SAMomentum over a gradient pytree in the flat arena.

    Selection stays per-tensor (paper Alg. 1 line 8 thresholds each
    parameter tensor separately) via arena views; the velocity is ONE
    packed buffer and the message ONE global-index SparseLeaf with indices
    rebased by leaf offset (DESIGN.md §8).

    Returns (msg: global-index SparseLeaf over the arena, new_state).
    """
    space = ParamSpace.from_tree(grads)
    msg, u_new = engine.samomentum_step_arena(
        state.velocity, space.pack(grads), space,
        momentum=momentum, lr=lr, ks=space.ks(density), spec=spec)
    return msg, SAMomentumState(velocity=u_new)
