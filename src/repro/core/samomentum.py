"""Sparsification-Aware Momentum (SAMomentum) — paper Eq. (11)/(12), Alg. 3.

Per parameter tensor, each step:

    u      <- m * u_prev + eta * grad          (velocity accumulation)
    thr    <- k-th largest |u|                 (static-k form of "R% of |u|")
    mask   <- |u| >  thr-equivalent top-k support
    g_sent <- u . mask                         (shipped to the server, WITH lr)
    u      <- where(mask, u, u / m)            (Alg.3 line 11:
                                                u += (1/m - 1) * u . !mask)

Sent coordinates keep their velocity (momentum survives the send); unsent
coordinates are pre-divided by m so that next step's ``m * u`` decay cancels,
which telescopes (Eq. 13) into

    u_{c+T} = m * u_c + eta * sum_{i=1..T} grad_{c+i}

i.e. vanilla momentum with the batch size adaptively enlarged T x per
coordinate — the paper's equivalence theorem, property-tested in
tests/test_samomentum.py.

No residual buffer exists (contrast DGC): the velocity itself carries the
unsent mass. This halves optimizer memory vs momentum-corrected DGC.

The accumulate/select/rescale operator itself lives in core/engine.py (one
implementation behind every DGS path); this module is the pytree-shaped
optimizer face of it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import engine
from .engine import CompressionSpec
from .sparsify import density_to_k


class SAMomentumState(NamedTuple):
    velocity: object  # pytree like params


def init(params) -> SAMomentumState:
    return SAMomentumState(velocity=jax.tree.map(jnp.zeros_like, params))


def leaf_update(
    u_prev: jax.Array,
    grad: jax.Array,
    *,
    momentum: float,
    lr: float,
    k: int,
    spec: CompressionSpec = engine.EXACT_SPEC,
):
    """Single-tensor SAMomentum step. Returns (msg: SparseLeaf, u_new)."""
    return engine.samomentum_step(
        u_prev, grad, momentum=momentum, lr=lr, k=k, spec=spec)


def leaf_update_dense(u_prev, grad, *, momentum, lr):
    """Degenerate density=1 case: every coordinate is sent each step, so
    SAMomentum is exactly heavy-ball momentum (paper Eq. 7/8)."""
    u = engine.velocity_accumulate(u_prev, grad, momentum=momentum, lr=lr)
    return u, u


def tree_update(
    state: SAMomentumState,
    grads,
    *,
    momentum: float,
    lr: float,
    density: float,
    spec: CompressionSpec = engine.EXACT_SPEC,
):
    """Per-leaf SAMomentum over a gradient pytree.

    Returns (msgs: list[SparseLeaf] in jax.tree.leaves order, new_state).
    """
    u_leaves, treedef = jax.tree.flatten(state.velocity)
    g_leaves = jax.tree.leaves(grads)
    msgs, new_u = [], []
    for u_prev, g in zip(u_leaves, g_leaves):
        k = density_to_k(int(u_prev.size), density)
        msg, u = leaf_update(u_prev, g, momentum=momentum, lr=lr, k=k,
                             spec=spec)
        msgs.append(msg)
        new_u.append(u)
    return msgs, SAMomentumState(velocity=jax.tree.unflatten(treedef, new_u))
