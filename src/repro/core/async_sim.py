"""Deterministic event-driven simulator for asynchronous PS training.

The paper's cluster is 32 GPU workers hitting one TCP parameter server at
their own pace.  On a single host we reproduce the *algorithmic* behaviour
exactly and deterministically:

* every worker owns a local model copy + strategy state (velocity/residual)
  — both packed into the flat parameter arena (core/paramspace.py),
* a schedule (sequence of worker ids, derived from simulated heterogeneous
  worker speeds) fixes the global order in which workers reach the server,
* each event executes: local backward on the worker's *stale* model ->
  strategy.step (sparsify) -> server.receive -> server.send (model diff,
  optionally secondary-compressed) -> worker applies G.

Staleness therefore emerges naturally: a slow worker computes gradients on a
model that is many server-updates old — exactly the regime the paper's
SAMomentum is designed to survive.

Each event runs as four jitted stages — client compute, server
receive+select, server commit, worker apply — the SAME stage functions the
federated cluster runtime (repro.cluster) executes on either side of its
wire and the scan runner (core/scan_runner.py) compiles into its fused
event body, with the codec's quantizer between them.  That shared
decomposition is what makes the simulator's losses bit-for-bit reproducible
on the real transport AND in the scan; byte accounting is the codec's
measured frame sizes (wire.frame_bytes) — static per event for sparse
messages, so it is computed ONCE per run (no per-event host sync).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_lib
from . import server as ps
from .baselines import Strategy, msgd_step
from .engine import CompressionSpec
from .paramspace import ParamSpace


def make_schedule(
    n_workers: int,
    n_events: int,
    *,
    seed: int = 0,
    hetero: float = 0.5,
) -> np.ndarray:
    """Event order from simulated worker speeds.

    Worker service times are exponential with per-worker rates drawn
    lognormal(0, hetero); hetero=0 degenerates to round-robin-ish fair
    interleaving, larger hetero produces stragglers and thus higher staleness.

    A heap-ordered event queue makes this O(n_events * log n_workers) — a
    million-event schedule for the scalability sweeps generates in seconds
    where the old per-event ``np.argmin`` scan was O(n_events * n_workers).
    The draw sequence is identical to the argmin loop (one exponential per
    event for the completing worker; ties resolve to the lowest worker id),
    so schedules are bit-for-bit what they always were.
    """
    rng = np.random.default_rng(seed)
    speeds = np.exp(rng.normal(0.0, hetero, n_workers))
    scale = 1.0 / speeds
    # next completion time per worker
    t_next = rng.exponential(scale)
    heap = [(float(t_next[k]), k) for k in range(n_workers)]
    heapq.heapify(heap)
    order = np.empty(n_events, dtype=np.int32)
    for e in range(n_events):
        t, k = heapq.heappop(heap)
        order[e] = k
        heapq.heappush(heap, (t + rng.exponential(scale[k]), k))
    return order


class History(NamedTuple):
    losses: np.ndarray          # (n_events,)
    worker_ids: np.ndarray      # (n_events,)
    staleness: np.ndarray       # (n_events,) server updates since last sync
    up_bytes: int               # total upward wire bytes
    down_bytes: int             # total downward wire bytes
    evals: list                 # [(event_idx, metric), ...]


def staleness_of(schedule, n_workers: int) -> np.ndarray:
    """Per-event staleness (server updates since the worker last synced) —
    a pure function of the schedule, shared by every runner."""
    last_sync = np.zeros(n_workers, dtype=np.int64)
    out = np.zeros(len(schedule), dtype=np.int64)
    for e, k in enumerate(schedule):
        out[e] = e - last_sync[k]
        last_sync[k] = e + 1
    return out


# ---------------------------------------------------------------------------
# The four per-event stages, decomposed exactly as the cluster runtime runs
# them (client compute | server receive+select | server commit | client
# apply).  AsyncTrainer and repro.cluster jit THESE SAME functions — and
# core/scan_runner.py inlines the raw ``*_fn`` forms into its scan body —
# so XLA compiles one identical op sequence for each stage and every runner
# is bit-for-bit reproducible on every other (tests/test_cluster.py,
# tests/test_scan_runner.py).  Wire quantization happens BETWEEN stages via
# wire.quantize_message — the codec's jitted segment-wise quantizer — never
# inside the strategy jit.
# ---------------------------------------------------------------------------

def strip_quantize(strategy: Strategy) -> Strategy:
    """The strategy with in-engine wire quantization disabled — message
    values leave the compute stage raw; the wire (or its in-process stand-in
    ``wire.quantize_message``) owns value quantization."""
    if strategy.quantize == "none":
        return strategy
    return dataclasses.replace(strategy, quantize="none")


def client_step_fn(strategy: Strategy, grad_fn, space: ParamSpace):
    """client compute: grads on the stale local model + strategy step.

    The worker model lives as a ``(total,)`` arena ``theta``; it is
    unpacked to the parameter pytree only for ``grad_fn``.  Returns
    (new strategy state, loss, RAW upward arena message).
    """
    strategy = strip_quantize(strategy)

    def client_step(theta, wstrat, batch, lr):
        loss, grads = grad_fn(space.unpack(theta), batch)
        wstrat, msg = strategy.step(wstrat, grads, lr)
        return wstrat, loss, msg

    return client_step


def make_client_step(strategy: Strategy, grad_fn, space: ParamSpace):
    """jit(client compute) over the arena model."""
    return jax.jit(client_step_fn(strategy, grad_fn, space))


def server_step_fn(secondary_density, spec: CompressionSpec):
    """server: apply the upward message, select the RAW downward one."""

    def server_step(sstate, msg, worker_id):
        sstate = ps.receive(sstate, msg)
        G = ps.send_select(sstate, worker_id,
                           secondary_density=secondary_density, spec=spec)
        return sstate, G

    return server_step


def make_server_step(secondary_density, spec: CompressionSpec):
    """jit(server): one fused scatter in, one subtract + per-tensor select
    out (the arena descriptor rides statically inside ServerState)."""
    return jax.jit(server_step_fn(secondary_density, spec))


def make_commit():
    """jit(server commit): fold the SHIPPED downward message into v_k."""
    return jax.jit(ps.send_commit)


def make_apply():
    """jit(worker apply): theta <- theta + G (Eq. 5) — one arena scatter."""
    return jax.jit(ps.apply_update)


@dataclasses.dataclass
class AsyncTrainer:
    """Asynchronous PS training loop over a gradient function.

    grad_fn(params, batch) -> (loss, grads)   [pure, jittable]
    """

    strategy: Strategy
    grad_fn: Callable
    n_workers: int
    lr: float
    secondary_density: float | None = None
    # engine/quantize spec for the server's secondary (downward) compression
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC

    def init(self, params0):
        space = ParamSpace.from_tree(params0)
        theta0 = space.pack(params0)
        workers = [
            {"theta": theta0, "strat": self.strategy.init(params0)}
            for _ in range(self.n_workers)
        ]
        return ps.init(params0, self.n_workers), workers

    def run(
        self,
        params0,
        schedule: np.ndarray,
        batch_fn: Callable[[int, int], Any],
        *,
        lr_fn: Callable[[int], float] | None = None,
        eval_fn: Callable | None = None,
        eval_every: int = 0,
    ):
        """Run the full schedule.  batch_fn(event_idx, worker_id) -> batch."""
        from repro.cluster import wire  # codec quantizer + byte accounting

        space = ParamSpace.from_tree(params0)
        sstate, workers = self.init(params0)
        client_step = make_client_step(self.strategy, self.grad_fn, space)
        server_step = make_server_step(self.secondary_density,
                                       self.secondary_spec)
        commit, apply_G = make_commit(), make_apply()
        up_mode = self.strategy.quantize
        down_mode = self.secondary_spec.quantize
        up_seg = self.strategy.message_seg(space)
        down_seg = (space.ks(self.secondary_density)
                    if self.secondary_density is not None else None)
        # frame sizes are static per (mode, seg, total) for sparse messages:
        # memoize the per-event cost once instead of re-deriving it from
        # on-device message structure every event (which cost a host sync);
        # dense messages stay per-event (their nnz is data-dependent)
        up_cost = (wire.frame_bytes_static(up_seg, space.total, up_mode)
                   if up_seg is not None else None)
        down_cost = (wire.frame_bytes_static(down_seg, space.total, down_mode)
                     if down_seg is not None else None)
        losses = np.zeros(len(schedule), dtype=np.float64)
        up_bytes = down_bytes = 0
        evals = []
        for e, k in enumerate(schedule):
            k = int(k)
            lr = self.lr if lr_fn is None else float(lr_fn(e))
            batch = batch_fn(e, k)
            wst, loss, msg = client_step(
                workers[k]["theta"], workers[k]["strat"], batch, lr)
            msg = wire.quantize_message(msg, up_mode, seg=up_seg)
            sstate, G = server_step(sstate, msg, jnp.int32(k))
            G = wire.quantize_message(G, down_mode, seg=down_seg)
            sstate = commit(sstate, jnp.int32(k), G)
            workers[k]["theta"] = apply_G(workers[k]["theta"], G)
            workers[k]["strat"] = wst
            losses[e] = float(loss)
            up_bytes += (up_cost if up_cost is not None
                         else wire.frame_bytes(msg, mode=up_mode))
            down_bytes += (down_cost if down_cost is not None
                           else wire.frame_bytes(G, mode=down_mode))
            if eval_fn is not None and eval_every and (e + 1) % eval_every == 0:
                model = ps.global_model(params0, sstate)
                evals.append((e + 1, eval_fn(model)))
        final = ps.global_model(params0, sstate)
        hist = History(
            losses=losses,
            worker_ids=np.asarray(schedule),
            staleness=staleness_of(schedule, self.n_workers),
            up_bytes=up_bytes,
            down_bytes=down_bytes,
            evals=evals,
        )
        return final, sstate, hist


def run_msgd(
    params0,
    grad_fn,
    batches,
    *,
    lr: float,
    momentum: float = 0.7,
    lr_fn=None,
):
    """Single-node momentum SGD baseline (paper's MSGD)."""
    velocity = jax.tree.map(jnp.zeros_like, params0)

    @jax.jit
    def step(params, velocity, batch, lr):
        loss, grads = grad_fn(params, batch)
        params, velocity = msgd_step(
            params, velocity, grads, lr=lr, momentum=momentum
        )
        return params, velocity, loss

    params = params0
    losses = []
    for e, b in enumerate(batches):
        cur_lr = lr if lr_fn is None else float(lr_fn(e))
        params, velocity, loss = step(params, velocity, b, cur_lr)
        losses.append(float(loss))
    return params, np.asarray(losses)
