"""Deterministic event-driven simulator for asynchronous PS training.

The paper's cluster is 32 GPU workers hitting one TCP parameter server at
their own pace.  On a single host we reproduce the *algorithmic* behaviour
exactly and deterministically:

* every worker owns a local model copy + strategy state (velocity/residual),
* a schedule (sequence of worker ids, derived from simulated heterogeneous
  worker speeds) fixes the global order in which workers reach the server,
* each event executes: local backward on the worker's *stale* model ->
  strategy.step (sparsify) -> server.receive -> server.send (model diff,
  optionally secondary-compressed) -> worker applies G.

Staleness therefore emerges naturally: a slow worker computes gradients on a
model that is many server-updates old — exactly the regime the paper's
SAMomentum is designed to survive.

The per-event exchange is one jitted function (donated worker/server state),
so simulating thousands of events with small models is fast on CPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_lib
from . import server as ps
from .baselines import Strategy, msgd_step
from .engine import CompressionSpec
from .sparsify import SparseLeaf, message_bytes


def make_schedule(
    n_workers: int,
    n_events: int,
    *,
    seed: int = 0,
    hetero: float = 0.5,
) -> np.ndarray:
    """Event order from simulated worker speeds.

    Worker service times are exponential with per-worker rates drawn
    lognormal(0, hetero); hetero=0 degenerates to round-robin-ish fair
    interleaving, larger hetero produces stragglers and thus higher staleness.
    """
    rng = np.random.default_rng(seed)
    speeds = np.exp(rng.normal(0.0, hetero, n_workers))
    # next completion time per worker
    t_next = rng.exponential(1.0 / speeds)
    order = np.empty(n_events, dtype=np.int32)
    for e in range(n_events):
        k = int(np.argmin(t_next))
        order[e] = k
        t_next[k] += rng.exponential(1.0 / speeds[k])
    return order


class History(NamedTuple):
    losses: np.ndarray          # (n_events,)
    worker_ids: np.ndarray      # (n_events,)
    staleness: np.ndarray       # (n_events,) server updates since last sync
    up_bytes: int               # total upward wire bytes
    down_bytes: int             # total downward wire bytes
    evals: list                 # [(event_idx, metric), ...]


@dataclasses.dataclass
class AsyncTrainer:
    """Asynchronous PS training loop over a gradient function.

    grad_fn(params, batch) -> (loss, grads)   [pure, jittable]
    """

    strategy: Strategy
    grad_fn: Callable
    n_workers: int
    lr: float
    secondary_density: float | None = None
    # engine/quantize spec for the server's secondary (downward) compression
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC

    def init(self, params0):
        workers = [
            {"params": params0, "strat": self.strategy.init(params0)}
            for _ in range(self.n_workers)
        ]
        return ps.init(params0, self.n_workers), workers

    def _exchange(self, sstate, wparams, wstrat, batch, worker_id, lr):
        loss, grads = self.grad_fn(wparams, batch)
        wstrat, msg = self.strategy.step(wstrat, grads, lr)
        sstate = ps.receive(sstate, msg)
        sstate, G = ps.send(
            sstate, worker_id, secondary_density=self.secondary_density,
            spec=self.secondary_spec,
        )
        wparams = ps.apply_to_params(wparams, G)
        return sstate, wparams, wstrat, loss, msg, G

    def run(
        self,
        params0,
        schedule: np.ndarray,
        batch_fn: Callable[[int, int], Any],
        *,
        lr_fn: Callable[[int], float] | None = None,
        eval_fn: Callable | None = None,
        eval_every: int = 0,
    ):
        """Run the full schedule.  batch_fn(event_idx, worker_id) -> batch."""
        sstate, workers = self.init(params0)
        exchange = jax.jit(self._exchange)
        last_sync = np.zeros(self.n_workers, dtype=np.int64)
        losses = np.zeros(len(schedule), dtype=np.float64)
        staleness = np.zeros(len(schedule), dtype=np.int64)
        up_bytes = down_bytes = 0
        evals = []
        for e, k in enumerate(schedule):
            k = int(k)
            lr = self.lr if lr_fn is None else float(lr_fn(e))
            batch = batch_fn(e, k)
            sstate, wp, wst, loss, msg, G = exchange(
                sstate, workers[k]["params"], workers[k]["strat"],
                batch, jnp.int32(k), lr,
            )
            workers[k]["params"], workers[k]["strat"] = wp, wst
            losses[e] = float(loss)
            staleness[e] = e - last_sync[k]
            last_sync[k] = e + 1
            vb = getattr(self.strategy, "value_bits", 32)
            up_bytes += _msg_bytes(msg, value_bits=vb)
            down_bytes += _msg_bytes(
                G, value_bits=self.secondary_spec.value_bits)
            if eval_fn is not None and eval_every and (e + 1) % eval_every == 0:
                model = ps.global_model(params0, sstate)
                evals.append((e + 1, eval_fn(model)))
        final = ps.global_model(params0, sstate)
        hist = History(
            losses=losses,
            worker_ids=np.asarray(schedule),
            staleness=staleness,
            up_bytes=up_bytes,
            down_bytes=down_bytes,
            evals=evals,
        )
        return final, sstate, hist


def _msg_bytes(msg, *, value_bits: int = 32) -> int:
    total = 0
    for m in msg:
        if isinstance(m, SparseLeaf):
            total += (m.values.size * value_bits) // 8 + m.indices.size * 4
        else:
            # dense downward diff: wire format would send nnz (value,index)
            # pairs when sparse is cheaper, else the dense vector.
            nnz = int(jnp.sum(m != 0.0))
            total += min(nnz * 8, m.size * m.dtype.itemsize)
    return total


def run_msgd(
    params0,
    grad_fn,
    batches,
    *,
    lr: float,
    momentum: float = 0.7,
    lr_fn=None,
):
    """Single-node momentum SGD baseline (paper's MSGD)."""
    velocity = jax.tree.map(jnp.zeros_like, params0)

    @jax.jit
    def step(params, velocity, batch, lr):
        loss, grads = grad_fn(params, batch)
        params, velocity = msgd_step(
            params, velocity, grads, lr=lr, momentum=momentum
        )
        return params, velocity, loss

    params = params0
    losses = []
    for e, b in enumerate(batches):
        cur_lr = lr if lr_fn is None else float(lr_fn(e))
        params, velocity, loss = step(params, velocity, b, cur_lr)
        losses.append(float(loss))
    return params, np.asarray(losses)
