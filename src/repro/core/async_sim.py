"""Deterministic event-driven simulator for asynchronous PS training.

The paper's cluster is 32 GPU workers hitting one TCP parameter server at
their own pace.  On a single host we reproduce the *algorithmic* behaviour
exactly and deterministically:

* every worker owns a local model copy + strategy state (velocity/residual)
  — both packed into the flat parameter arena (core/paramspace.py),
* a schedule (sequence of worker ids, derived from simulated heterogeneous
  worker speeds) fixes the global order in which workers reach the server,
* each event executes: local backward on the worker's *stale* model ->
  strategy.step (sparsify) -> server.receive -> server.send (model diff,
  optionally secondary-compressed) -> worker applies G.

Staleness therefore emerges naturally: a slow worker computes gradients on a
model that is many server-updates old — exactly the regime the paper's
SAMomentum is designed to survive.

Each event runs as four jitted stages — client compute, server
receive+select, server commit, worker apply — the SAME stage functions the
federated cluster runtime (repro.cluster) executes on either side of its
wire and the scan runner (core/scan_runner.py) compiles into its fused
event body, with the codec's quantizer between them.  That shared
decomposition is what makes the simulator's losses bit-for-bit reproducible
on the real transport AND in the scan; byte accounting is the codec's
measured frame sizes (wire.frame_bytes) — static per event for sparse
messages, so it is computed ONCE per run (no per-event host sync).

Two event loops share those stages (DESIGN.md §9):

* ``AsyncTrainer.run``          — serial reference: one event at a time.
* ``AsyncTrainer.run_batched``  — ``batch_schedule`` groups maximal runs of
  PAIRWISE-DISTINCT workers into one dispatch per stage: the client stage
  vmaps over the batch (independent stale models), the server receives run
  as a ``lax.scan`` inside one jit (each event's select must see the M its
  predecessors left — prefix-dependent, so sequential-in-graph), and the
  commits fuse into ONE multi-row scatter (disjoint ``v`` rows commute
  bitwise).  Bit-for-bit equal to the serial loop — losses, params, AND
  byte accounting (tests/test_async_sim.py).

All batched stages and the serial server/commit/apply stages donate their
state arguments (``M``/``v``/theta/velocity arenas update in place — no
per-event buffer churn).  The one exception is the serial client step: see
``make_client_step`` for why its state stays un-donated.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_lib
from . import server as ps
from .baselines import Strategy, msgd_step
from .engine import CompressionSpec
from .paramspace import ParamSpace
from .sparsify import SparseLeaf


def make_schedule(
    n_workers: int,
    n_events: int,
    *,
    seed: int = 0,
    hetero: float = 0.5,
) -> np.ndarray:
    """Event order from simulated worker speeds.

    Worker service times are exponential with per-worker rates drawn
    lognormal(0, hetero); hetero=0 degenerates to round-robin-ish fair
    interleaving, larger hetero produces stragglers and thus higher staleness.

    A heap-ordered event queue makes this O(n_events * log n_workers) — a
    million-event schedule for the scalability sweeps generates in seconds
    where the old per-event ``np.argmin`` scan was O(n_events * n_workers).
    The draw sequence is identical to the argmin loop (one exponential per
    event for the completing worker; ties resolve to the lowest worker id),
    so schedules are bit-for-bit what they always were.
    """
    rng = np.random.default_rng(seed)
    speeds = np.exp(rng.normal(0.0, hetero, n_workers))
    scale = 1.0 / speeds
    # next completion time per worker
    t_next = rng.exponential(scale)
    heap = [(float(t_next[k]), k) for k in range(n_workers)]
    heapq.heapify(heap)
    order = np.empty(n_events, dtype=np.int32)
    for e in range(n_events):
        t, k = heapq.heappop(heap)
        order[e] = k
        heapq.heappush(heap, (t + rng.exponential(scale[k]), k))
    return order


class History(NamedTuple):
    losses: np.ndarray          # (n_events,)
    worker_ids: np.ndarray      # (n_events,)
    staleness: np.ndarray       # (n_events,) server updates since last sync
    up_bytes: int               # total upward wire bytes
    down_bytes: int             # total downward wire bytes
    evals: list                 # [(event_idx, metric), ...]
    # drained flight-recorder metrics (repro.telemetry) when the run was
    # told to collect them; None otherwise — the data plane is identical
    # either way (test_metrics_do_not_change_bits)
    metrics: dict | None = None


def _jsonable(x):
    """Best-effort scalarization of an eval metric for the JSONL log."""
    try:
        return float(x)
    except (TypeError, ValueError):
        return str(x)


def _record_run_summary(rec, runner: str, hist: History,
                        up_cost, down_cost, per_up, per_down) -> None:
    """Emit the end-of-run JSONL summary the report renderer consumes:
    staleness + per-event wire-byte histograms (host data only — the run
    is over, so this syncs nothing)."""
    if not rec.enabled:
        return
    from repro.telemetry import metrics as metrics_lib

    n = len(hist.losses)
    if per_up is None:
        per_up = np.full(n, up_cost if up_cost is not None else 0)
    if per_down is None:
        per_down = np.full(n, down_cost if down_cost is not None else 0)
    rec.event(
        "run_summary", runner=runner, n_events=n,
        up_bytes=int(hist.up_bytes), down_bytes=int(hist.down_bytes),
        loss_first=float(hist.losses[0]) if n else None,
        loss_last=float(hist.losses[-1]) if n else None,
        staleness_hist=metrics_lib.summarize_log2(hist.staleness),
        up_bytes_hist=metrics_lib.summarize_log2(per_up),
        down_bytes_hist=metrics_lib.summarize_log2(per_down),
        metrics=hist.metrics,
    )


def staleness_of(schedule, n_workers: int) -> np.ndarray:
    """Per-event staleness (server updates since the worker last synced) —
    a pure function of the schedule, shared by every runner."""
    last_sync = np.zeros(n_workers, dtype=np.int64)
    out = np.zeros(len(schedule), dtype=np.int64)
    for e, k in enumerate(schedule):
        out[e] = e - last_sync[k]
        last_sync[k] = e + 1
    return out


def batch_schedule(
    schedule,
    *,
    max_batch: int | None = None,
    cut_every: int | None = None,
) -> list[np.ndarray]:
    """Group a schedule into batches of independent events (the batched
    scheduler view).

    A batch is a maximal run of CONSECUTIVE events with pairwise-distinct
    workers, truncated to a power-of-two length.  Distinctness is the
    commutation rule (DESIGN.md §9): within such a run every event reads a
    different worker model and commits to a different ``v`` row, so the
    client computes vmap and the commits fuse into one multi-row scatter
    while remaining bit-equal to serial execution.  The power-of-two
    truncation bounds jit specialization to O(log n_workers) batch sizes.

    ``cut_every`` forces batch boundaries at multiples of that many events
    (evaluation points); ``max_batch`` caps the batch size.  Invariant:
    ``np.concatenate(batch_schedule(s)) == s`` — batching never reorders.
    """
    sched = np.asarray(schedule)
    n = len(sched)
    batches = []
    i = 0
    while i < n:
        limit = n
        if cut_every:
            limit = min(limit, (i // cut_every + 1) * cut_every)
        if max_batch is not None:
            limit = min(limit, i + max_batch)
        seen = set()
        j = i
        while j < limit and sched[j] not in seen:
            seen.add(sched[j])
            j += 1
        size = 1 << ((j - i).bit_length() - 1)   # pow2 truncation
        batches.append(sched[i:i + size])
        i += size
    return batches


# ---------------------------------------------------------------------------
# The four per-event stages, decomposed exactly as the cluster runtime runs
# them (client compute | server receive+select | server commit | client
# apply).  AsyncTrainer and repro.cluster jit THESE SAME functions — and
# core/scan_runner.py inlines the raw ``*_fn`` forms into its scan body —
# so XLA compiles one identical op sequence for each stage and every runner
# is bit-for-bit reproducible on every other (tests/test_cluster.py,
# tests/test_scan_runner.py).  Wire quantization happens BETWEEN stages via
# wire.quantize_message — the codec's jitted segment-wise quantizer — never
# inside the strategy jit.
# ---------------------------------------------------------------------------

def strip_quantize(strategy: Strategy) -> Strategy:
    """The strategy with in-engine wire quantization disabled — message
    values leave the compute stage raw; the wire (or its in-process stand-in
    ``wire.quantize_message``) owns value quantization."""
    if strategy.quantize == "none":
        return strategy
    return dataclasses.replace(strategy, quantize="none")


def client_step_fn(strategy: Strategy, grad_fn, space: ParamSpace):
    """client compute: grads on the stale local model + strategy step.

    The worker model lives as a ``(total,)`` arena ``theta``; it is
    unpacked to the parameter pytree only for ``grad_fn``.  Returns
    (new strategy state, loss, RAW upward arena message).
    """
    strategy = strip_quantize(strategy)

    def client_step(theta, wstrat, batch, lr):
        loss, grads = grad_fn(space.unpack(theta), batch)
        wstrat, msg = strategy.step(wstrat, grads, lr)
        return wstrat, loss, msg

    return client_step


def make_client_step(strategy: Strategy, grad_fn, space: ParamSpace):
    """jit(client compute) over the arena model.

    The strategy state is deliberately NOT donated here: donating it lets
    XLA fuse the momentum update in place, and on CPU that compiles to a
    program whose DGC velocity arithmetic differs by 1 ulp from the
    non-donated (and vmapped batched) compilation — which breaks the
    bit-for-bit serial/batched contract this loop is the reference for.
    The serial loop is the baseline, not the fast path; the batched loop
    donates everything (verified bit-equal against this reference).
    """
    return jax.jit(client_step_fn(strategy, grad_fn, space))


def server_step_fn(secondary_density, spec: CompressionSpec):
    """server: apply the upward message, select the RAW downward one."""

    def server_step(sstate, msg, worker_id):
        sstate = ps.receive(sstate, msg)
        G = ps.send_select(sstate, worker_id,
                           secondary_density=secondary_density, spec=spec)
        return sstate, G

    return server_step


def make_server_step(secondary_density, spec: CompressionSpec):
    """jit(server): one fused scatter in, one subtract + per-tensor select
    out (the arena descriptor rides statically inside ServerState).
    ``sstate`` is donated — the M arena updates in place."""
    return jax.jit(server_step_fn(secondary_density, spec),
                   donate_argnums=(0,))


def make_commit():
    """jit(server commit): fold the SHIPPED downward message into v_k.
    ``sstate`` is donated — the v buffer updates in place."""
    return jax.jit(ps.send_commit, donate_argnums=(0,))


def make_apply():
    """jit(worker apply): theta <- theta + G (Eq. 5) — one arena scatter.
    ``theta`` is donated — the worker model updates in place."""
    return jax.jit(ps.apply_update, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Batched stage factories (run_batched / cluster batched drain).  Worker
# models and strategy states live STACKED — wp: (n_workers, total), ws: the
# strategy pytree with a leading (n_workers,) axis — and every stage takes
# the batch's worker ids, gathering/scattering rows in-graph.  Each factory
# mirrors its serial twin's jit boundary, so XLA materializes the same
# stage edges and the arithmetic stays bit-equal (DESIGN.md §9).
# ---------------------------------------------------------------------------

def make_batched_client_step(strategy: Strategy, grad_fn, space: ParamSpace):
    """jit(vmap(client compute)) across the ready-worker batch.

    Takes the stacked worker arenas, gathers the batch rows, vmaps the
    SAME ``client_step_fn`` body across them (independent stale models —
    the batching rule guarantees distinct workers), and writes the updated
    strategy rows back.  Also emits the per-event nnz of dense upward
    messages (byte accounting without a per-event host sync); donates the
    stacked strategy state.
    """
    vstep = jax.vmap(client_step_fn(strategy, grad_fn, space))
    dense_msg = not strategy.sparse

    def run(wp, ws, ids, batches, lrs):
        st = jax.tree.map(lambda s: s[ids], ws)
        st2, losses, msgs = vstep(wp[ids], st, batches, lrs)
        ws = jax.tree.map(lambda s, r: s.at[ids].set(r), ws, st2)
        nnz = (jnp.sum(msgs != 0.0, axis=-1) if dense_msg
               else jnp.zeros(ids.shape, jnp.int32))
        return ws, losses, msgs, nnz

    return jax.jit(run, donate_argnums=(1,))


def make_batched_quantize(mode: str, seg):
    """jit(vmap(wire.quantize_message)) over a stacked sparse message, or
    None when quantization is a no-op (mode "none", or dense messages —
    they travel f32)."""
    from repro.cluster import wire
    if mode == "none" or seg is None:
        return None
    seg = tuple(int(s) for s in seg)
    return jax.jit(jax.vmap(
        lambda m: wire.quantize_message(m, mode, seg=seg)))


def batched_server_step_fn(secondary_density, spec: CompressionSpec):
    """server over a whole batch: receive each message, select each RAW
    downward message against the M its predecessors left.

    The receives into M are PREFIX-dependent — event i's select must see
    exactly the post-receive M of events 0..i — so they run as a
    ``lax.scan`` carrying ``(M, t)`` inside ONE jit: sequential in the
    graph, one dispatch on the host.  The ``v`` rows read are untouched
    within the batch (pairwise-distinct workers), so they gather up front.

    Returns ``(sstate, G, M_rows)``: G the stacked raw downward batch;
    ``M_rows`` the per-event prefix M stack when the downward message is
    dense (``secondary_density is None`` — the commit's ``v_k <- M`` snap
    must use M *as of that event*, see ``server.send_commit_rows``), else
    ``None``.
    """
    dense_down = secondary_density is None
    spec_raw = dataclasses.replace(spec, quantize="none")

    def server_batch(sstate, msgs, ids):
        v_rows = sstate.v[ids]

        def body(carry, x):
            M, t = carry
            msg, v_k = x
            st = ps.receive(sstate._replace(M=M, t=t), msg)
            diff = st.M - v_k
            if dense_down:
                out = (diff, st.M)
            else:
                out = (st.space.select(
                    diff, st.space.ks(secondary_density), spec_raw),)
            return (st.M, st.t), out

        (M, t), outs = jax.lax.scan(body, (sstate.M, sstate.t),
                                    (msgs, v_rows))
        sstate = sstate._replace(M=M, t=t)
        if dense_down:
            return sstate, outs[0], outs[1]
        return sstate, outs[0], None

    return server_batch


def make_batched_server_step(secondary_density, spec: CompressionSpec):
    """jit(batched server); donates ``sstate``."""
    return jax.jit(batched_server_step_fn(secondary_density, spec),
                   donate_argnums=(0,))


def make_batched_commit(dense_down: bool):
    """jit(batched commit): fold a whole SHIPPED batch into its ``v`` rows
    with ONE fused multi-row scatter (``server.send_commit_rows``).

    The dense variant takes the batched server step's prefix ``M_rows``
    (snap rule) and also emits each event's downward nnz for byte
    accounting.  Donates ``sstate``.
    """
    if dense_down:
        def commit(sstate, ids, G, M_rows):
            sstate = ps.send_commit_rows(sstate, ids, G, M_rows)
            return sstate, jnp.sum(G != 0.0, axis=-1)
    else:
        def commit(sstate, ids, G):
            return ps.send_commit_rows(sstate, ids, G)
    return jax.jit(commit, donate_argnums=(0,))


def mesh_batched_server_step_fn(secondary_density, spec: CompressionSpec):
    """Mesh-sharded twin of :func:`batched_server_step_fn` — same call
    signature, same outputs, but ``sstate`` is a
    :class:`server.MeshServerState` and ALL S shard servers run inside
    this one stage (DESIGN.md §14).

    A sparse upward batch is routed ONCE through the in-graph alltoallv
    (``distributed.shard_exchange_batch``) before the prefix scan; each
    scan step then applies one fused per-shard scatter into the stacked
    ``(S, width)`` M.  Selection happens on the re-concatenated GLOBAL
    diff through the same ``ParamSpace.select``, so the downward message
    (and its wire bytes) are bit-identical to the flat server's.
    """
    dense_down = secondary_density is None
    spec_raw = dataclasses.replace(spec, quantize="none")

    def server_batch(sstate, msgs, ids):
        from repro.core import distributed
        sspec = sstate.spec
        S = sspec.n_shards
        width = sstate.M.shape[1]
        v_rows = sstate.v[ids]                       # (B, S, width)
        rows2d = jnp.arange(S, dtype=jnp.int32)[:, None]
        sparse_up = isinstance(msgs, SparseLeaf)
        if sparse_up:
            ri, rv, ovf = distributed.shard_exchange_batch(
                sspec, msgs.indices, msgs.values)    # (B, S, slots)
            xs = (ri, rv, v_rows)
        else:
            ups = jax.vmap(
                lambda m: ps.mesh_split(sspec, m, width))(msgs)
            ovf = jnp.zeros((), jnp.int32)
            xs = (ups, v_rows)

        def body(carry, x):
            M, t = carry
            if sparse_up:
                ri_b, rv_b, v_k = x
                # one fused scatter per shard: empty (-1) slots dump into
                # the padding column width, which is sliced away
                cols = jnp.where(ri_b >= 0, ri_b, width)
                Mp = jnp.concatenate(
                    [M, jnp.zeros((S, 1), M.dtype)], axis=1)
                M = Mp.at[rows2d, cols].add(-rv_b)[:, :-1]
            else:
                up_b, v_k = x
                M = M - up_b
            t = t + 1
            diff_flat = ps.mesh_concat(sspec, M - v_k)
            if dense_down:
                out = (diff_flat, M)
            else:
                out = (sstate.space.select(
                    diff_flat, sstate.space.ks(secondary_density),
                    spec_raw),)
            return (M, t), out

        (M, t), outs = jax.lax.scan(body, (sstate.M, sstate.t), xs)
        sstate = sstate._replace(M=M, t=t, overflow=sstate.overflow + ovf)
        if dense_down:
            return sstate, outs[0], outs[1]
        return sstate, outs[0], None

    return server_batch


def make_mesh_batched_server_step(secondary_density, spec: CompressionSpec):
    """jit(mesh batched server); donates ``sstate``."""
    return jax.jit(mesh_batched_server_step_fn(secondary_density, spec),
                   donate_argnums=(0,))


def make_mesh_batched_commit(dense_down: bool):
    """Mesh twin of :func:`make_batched_commit` — same call signature.

    Sparse commits route the SHIPPED batch through the same alltoallv as
    the receive and land in ``v`` with ONE fused 3-D scatter (distinct
    worker rows x per-shard slots); dense commits snap each ``v`` row to
    the per-event prefix ``M_rows`` stack.  Donates ``sstate``.
    """
    if dense_down:
        def commit(sstate, ids, G, M_rows):
            # M_rows: (B, S, width) mesh prefix states; G: (B, total)
            sstate = sstate._replace(v=sstate.v.at[ids].set(M_rows))
            return sstate, jnp.sum(G != 0.0, axis=-1)
    else:
        def commit(sstate, ids, G):
            from repro.core import distributed
            sspec = sstate.spec
            S = sspec.n_shards
            width = sstate.v.shape[-1]
            ri, rv, ovf = distributed.shard_exchange_batch(
                sspec, G.indices, G.values)          # (B, S, slots)
            cols = jnp.where(ri >= 0, ri, width)
            vp = jnp.concatenate(
                [sstate.v,
                 jnp.zeros(sstate.v.shape[:2] + (1,), sstate.v.dtype)],
                axis=2)
            new_v = vp.at[
                ids[:, None, None],
                jnp.arange(S, dtype=jnp.int32)[None, :, None],
                cols].add(rv)[:, :, :-1]
            return sstate._replace(v=new_v,
                                   overflow=sstate.overflow + ovf)
    return jax.jit(commit, donate_argnums=(0,))


def make_batched_apply():
    """jit(vmap(worker apply)) over the batch rows of the stacked worker
    models; donates ``wp`` (the (n_workers, total) buffer updates in
    place)."""
    vapply = jax.vmap(ps.apply_update)

    def apply_rows(wp, ids, G):
        return wp.at[ids].set(vapply(wp[ids], G))

    return jax.jit(apply_rows, donate_argnums=(0,))


@dataclasses.dataclass
class AsyncTrainer:
    """Asynchronous PS training loop over a gradient function.

    grad_fn(params, batch) -> (loss, grads)   [pure, jittable]
    """

    strategy: Strategy
    grad_fn: Callable
    n_workers: int
    lr: float
    secondary_density: float | None = None
    # engine/quantize spec for the server's secondary (downward) compression
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC

    def _serial_stages(self, space: ParamSpace):
        """The four jitted serial stages, memoized per arena layout.

        ``jax.jit`` caches compilations per wrapper object, so rebuilding
        the wrappers every :meth:`run` would recompile every stage on
        every call — the trainer instance keeps them across runs.
        """
        cached = getattr(self, "_serial_cache", None)
        if cached is None or cached[0] != space:
            stages = (make_client_step(self.strategy, self.grad_fn, space),
                      make_server_step(self.secondary_density,
                                       self.secondary_spec),
                      make_commit(), make_apply())
            self._serial_cache = cached = (space, stages)
        return cached[1]

    def _batched_stages(self, space: ParamSpace):
        """The batched stage bundle (client/server/commit/apply + the two
        vmapped wire quantizers), memoized like :meth:`_serial_stages`."""
        cached = getattr(self, "_batched_cache", None)
        if cached is None or cached[0] != space:
            up_seg = self.strategy.message_seg(space)
            down_seg = (None if self.secondary_density is None
                        else space.ks(self.secondary_density))
            stages = (
                make_batched_client_step(self.strategy, self.grad_fn,
                                         space),
                make_batched_server_step(self.secondary_density,
                                         self.secondary_spec),
                make_batched_commit(self.secondary_density is None),
                make_batched_apply(),
                make_batched_quantize(self.strategy.quantize, up_seg),
                make_batched_quantize(self.secondary_spec.quantize,
                                      down_seg),
            )
            self._batched_cache = cached = (space, stages)
        return cached[1]

    def _metrics_step(self):
        """The jitted telemetry fold (repro.telemetry.metrics), memoized
        like the stages.  It is a SEPARATE executable that only reads
        stage outputs, so enabling metrics never changes the data-plane
        compilations (the bit-for-bit invariant)."""
        cached = getattr(self, "_metrics_cache", None)
        if cached is None:
            from repro.telemetry import metrics as metrics_lib
            self._metrics_cache = cached = metrics_lib.make_metrics_step()
        return cached

    def init(self, params0):
        space = ParamSpace.from_tree(params0)
        theta0 = space.pack(params0)
        workers = [
            # per-worker theta COPIES: the apply stage donates its theta
            # argument, and donating a buffer shared by every worker would
            # invalidate the others' models
            {"theta": jnp.copy(theta0), "strat": self.strategy.init(params0)}
            for _ in range(self.n_workers)
        ]
        return ps.init(params0, self.n_workers), workers

    def run(
        self,
        params0,
        schedule: np.ndarray,
        batch_fn: Callable[[int, int], Any],
        *,
        lr_fn: Callable[[int], float] | None = None,
        eval_fn: Callable | None = None,
        eval_every: int = 0,
        recorder=None,
        metrics: bool = False,
    ):
        """Run the full schedule.  batch_fn(event_idx, worker_id) -> batch.

        ``recorder`` (a :class:`repro.telemetry.Recorder`) traces per-event
        host spans + run events; ``metrics=True`` folds every event into an
        on-device :class:`~repro.telemetry.metrics.MetricsState` (drained
        into ``History.metrics`` at the end).  Both default OFF, leaving
        this loop byte-identical to the untelemetered path.
        """
        from repro.cluster import wire  # codec quantizer + byte accounting
        from repro import telemetry
        from repro.telemetry import metrics as metrics_lib

        rec = recorder if recorder is not None else telemetry.NULL
        space = ParamSpace.from_tree(params0)
        sstate, workers = self.init(params0)
        client_step, server_step, commit, apply_G = \
            self._serial_stages(space)
        up_mode = self.strategy.quantize
        down_mode = self.secondary_spec.quantize
        up_seg = self.strategy.message_seg(space)
        down_seg = (space.ks(self.secondary_density)
                    if self.secondary_density is not None else None)
        # frame sizes are static per (mode, seg, total) for sparse messages:
        # memoize the per-event cost once instead of re-deriving it from
        # on-device message structure every event (which cost a host sync);
        # dense messages stay per-event (their nnz is data-dependent)
        up_cost = (wire.frame_bytes_static(up_seg, space.total, up_mode)
                   if up_seg is not None else None)
        down_cost = (wire.frame_bytes_static(down_seg, space.total, down_mode)
                     if down_seg is not None else None)
        # history stays ON DEVICE during the loop (scalars per event); it
        # materializes ONCE at the end — a per-event float(loss) would
        # round-trip the host and stall the dispatch pipeline every event
        losses: list = []
        up_nnz: list = []       # dense up messages: data-dependent nnz
        down_nnz: list = []     # dense down messages: data-dependent nnz
        up_bytes = down_bytes = 0
        evals = []
        stal = staleness_of(schedule, self.n_workers)  # host precomputed
        ms = metrics_lib.init(self.n_workers) if metrics else None
        mstep = self._metrics_step() if metrics else None
        for e, k in enumerate(schedule):
            k = int(k)
            lr = self.lr if lr_fn is None else float(lr_fn(e))
            with rec.span("sim/batch_build", worker=k):
                batch = batch_fn(e, k)
            with rec.span("sim/client_step", worker=k):
                wst, loss, msg = client_step(
                    workers[k]["theta"], workers[k]["strat"], batch, lr)
            with rec.span("sim/wire_quantize"):
                msg = wire.quantize_message(msg, up_mode, seg=up_seg)
            with rec.span("sim/server_step"):
                sstate, G = server_step(sstate, msg, jnp.int32(k))
                G = wire.quantize_message(G, down_mode, seg=down_seg)
            with rec.span("sim/commit"):
                sstate = commit(sstate, jnp.int32(k), G)
            with rec.span("sim/apply"):
                workers[k]["theta"] = apply_G(workers[k]["theta"], G)
            workers[k]["strat"] = wst
            losses.append(loss)
            if ms is not None:
                # one extra dispatch reading the SHIPPED messages; device
                # scalars only — no host sync in the loop
                ms = mstep(ms, np.int32(k), np.int32(stal[e]), msg, G)
            if up_cost is not None:
                up_bytes += up_cost
            else:
                up_nnz.append(jnp.count_nonzero(msg))
            if down_cost is not None:
                down_bytes += down_cost
            else:
                down_nnz.append(jnp.count_nonzero(G))
            if eval_fn is not None and eval_every and (e + 1) % eval_every == 0:
                with rec.span("sim/eval", event=e + 1):
                    model = ps.global_model(params0, sstate)
                    evals.append((e + 1, eval_fn(model)))
                # eval boundary = the sanctioned drain point
                rec.event("eval", event=e + 1, metric=_jsonable(evals[-1][1]),
                          **({"metrics": metrics_lib.drain(ms)}
                             if ms is not None else {}))
        final = ps.global_model(params0, sstate)
        per_up = per_down = None
        if up_nnz:
            per_up = (wire.ENVELOPE_BYTES + wire.dense_frame_bytes(
                np.asarray(jnp.stack(up_nnz)), space.total))
            up_bytes += int(np.sum(per_up))
        if down_nnz:
            per_down = (wire.ENVELOPE_BYTES + wire.dense_frame_bytes(
                np.asarray(jnp.stack(down_nnz)), space.total))
            down_bytes += int(np.sum(per_down))
        hist = History(
            losses=np.asarray(jnp.stack(losses), np.float64),
            worker_ids=np.asarray(schedule),
            staleness=stal,
            up_bytes=up_bytes,
            down_bytes=down_bytes,
            evals=evals,
            metrics=metrics_lib.drain(ms) if ms is not None else None,
        )
        _record_run_summary(rec, "serial", hist, up_cost, down_cost,
                            per_up, per_down)
        return final, sstate, hist

    def run_batched(
        self,
        params0,
        schedule: np.ndarray,
        batch_fn: Callable[[int, int], Any],
        *,
        lr_fn: Callable[[int], float] | None = None,
        eval_fn: Callable | None = None,
        eval_every: int = 0,
        max_batch: int | None = None,
        recorder=None,
        metrics: bool = False,
    ):
        """Batched event loop — bit-for-bit equal to :meth:`run`.

        ``batch_schedule`` groups runs of pairwise-distinct workers; each
        batch then costs ONE dispatch per stage (vmapped client compute,
        scanned server receive+select, fused multi-row commit, vmapped
        apply) instead of four-plus dispatches per event.  Worker models
        and strategy states live stacked — ``(n_workers, total)`` arenas —
        and every stage donates its state, so the whole fleet updates in
        place.  Losses, final params, and byte accounting match the serial
        loop exactly on the same schedule (tests/test_async_sim.py).

        ``recorder``/``metrics`` mirror :meth:`run`: host spans per batch,
        one on-device metrics fold per batch (whole-batch lanes in one
        dispatch), zero host syncs, no data-plane change.
        """
        from repro.cluster import wire
        from repro import telemetry
        from repro.telemetry import metrics as metrics_lib

        rec = recorder if recorder is not None else telemetry.NULL
        space = ParamSpace.from_tree(params0)
        sstate = ps.init(params0, self.n_workers)
        theta0 = space.pack(params0)
        n = self.n_workers
        # jnp.copy: donation needs owned buffers, not broadcast views
        wp = jnp.copy(jnp.broadcast_to(theta0[None], (n, space.total)))
        ws = jax.tree.map(
            lambda s: jnp.copy(jnp.broadcast_to(s[None], (n,) + s.shape)),
            self.strategy.init(params0))
        client, server, commit, apply_rows, q_up, q_down = \
            self._batched_stages(space)
        dense_down = self.secondary_density is None
        up_mode = self.strategy.quantize
        down_mode = self.secondary_spec.quantize
        up_seg = self.strategy.message_seg(space)
        down_seg = None if dense_down else space.ks(self.secondary_density)
        up_cost = (wire.frame_bytes_static(up_seg, space.total, up_mode)
                   if up_seg is not None else None)
        down_cost = (wire.frame_bytes_static(down_seg, space.total,
                                             down_mode)
                     if down_seg is not None else None)

        batches = batch_schedule(schedule, max_batch=max_batch,
                                 cut_every=eval_every or None)
        stal = staleness_of(schedule, self.n_workers)
        ms = metrics_lib.init(self.n_workers) if metrics else None
        mstep = self._metrics_step() if metrics else None
        losses, up_nnz, down_nnz, evals = [], [], [], []
        e = 0
        for ids_np in batches:
            b = len(ids_np)
            # numpy operands: the jit call converts them on its C++ fast
            # path — cheaper than one eager device dispatch per array
            ids = np.asarray(ids_np, np.int32)
            lrs = np.asarray(
                [self.lr if lr_fn is None else float(lr_fn(e + i))
                 for i in range(b)], np.float32)
            with rec.span("batched/batch_build", size=b):
                data = [batch_fn(e + i, int(k)) for i, k in enumerate(ids_np)]
                data = jax.tree.map(lambda *xs: jnp.stack(xs), *data)
            with rec.span("batched/client", size=b):
                ws, batch_losses, msgs, nnz_up = client(wp, ws, ids, data,
                                                        lrs)
                if q_up is not None:
                    msgs = q_up(msgs)
            with rec.span("batched/server", size=b):
                sstate, G, M_rows = server(sstate, msgs, ids)
            with rec.span("batched/commit", size=b):
                if dense_down:
                    sstate, nnz_dn = commit(sstate, ids, G, M_rows)
                    down_nnz.append(nnz_dn)
                else:
                    if q_down is not None:
                        G = q_down(G)
                    sstate = commit(sstate, ids, G)
            with rec.span("batched/apply", size=b):
                wp = apply_rows(wp, ids, G)
            losses.append(batch_losses)
            if ms is not None:
                # whole batch folded in one dispatch; staleness is the
                # host-precomputed schedule function — still no syncs
                ms = mstep(ms, ids, stal[e:e + b].astype(np.int32), msgs, G)
            if up_cost is None:
                up_nnz.append(nnz_up)
            e += b
            if eval_fn is not None and eval_every and e % eval_every == 0:
                with rec.span("batched/eval", event=e):
                    model = ps.global_model(params0, sstate)
                    evals.append((e, eval_fn(model)))
                rec.event("eval", event=e, metric=_jsonable(evals[-1][1]),
                          **({"metrics": metrics_lib.drain(ms)}
                             if ms is not None else {}))
        final = ps.global_model(params0, sstate)
        n_events = len(schedule)
        per_up = per_down = None
        if up_cost is not None:
            up_bytes = up_cost * n_events
        else:
            per_up = (wire.ENVELOPE_BYTES + wire.dense_frame_bytes(
                np.asarray(jnp.concatenate(up_nnz)), space.total))
            up_bytes = int(np.sum(per_up))
        if down_cost is not None:
            down_bytes = down_cost * n_events
        else:
            per_down = (wire.ENVELOPE_BYTES + wire.dense_frame_bytes(
                np.asarray(jnp.concatenate(down_nnz)), space.total))
            down_bytes = int(np.sum(per_down))
        hist = History(
            losses=np.asarray(jnp.concatenate(losses), np.float64),
            worker_ids=np.asarray(schedule),
            staleness=stal,
            up_bytes=up_bytes,
            down_bytes=down_bytes,
            evals=evals,
            metrics=metrics_lib.drain(ms) if ms is not None else None,
        )
        _record_run_summary(rec, "batched", hist, up_cost, down_cost,
                            per_up, per_down)
        return final, sstate, hist


def run_msgd(
    params0,
    grad_fn,
    batches,
    *,
    lr: float,
    momentum: float = 0.7,
    lr_fn=None,
):
    """Single-node momentum SGD baseline (paper's MSGD)."""
    velocity = jax.tree.map(jnp.zeros_like, params0)

    @jax.jit
    def step(params, velocity, batch, lr):
        loss, grads = grad_fn(params, batch)
        params, velocity = msgd_step(
            params, velocity, grads, lr=lr, momentum=momentum
        )
        return params, velocity, loss

    params = params0
    losses = []
    for e, b in enumerate(batches):
        cur_lr = lr if lr_fn is None else float(lr_fn(e))
        params, velocity, loss = step(params, velocity, b, cur_lr)
        losses.append(float(loss))
    return params, np.asarray(losses)
