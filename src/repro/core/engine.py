"""The compression engine: ONE pluggable top-k selector behind every DGS path.

Every sparsified exchange in this repo — the async-sim strategies
(baselines.py), the parameter server's secondary compression (server.py),
and the mesh collectives (distributed.py) — reduces to the same operator:

    select the top-k |x| support of a tensor (or of each row of a 2-D
    row view), optionally after a SAMomentum velocity accumulate, and
    rescale the unsent remainder so its mass telescopes into the velocity.

This module is the single implementation of that operator (DESIGN.md
§10 Compression-engine).  Three engines share the semantics contract written
down in ``kernels/ref.py``:

* ``exact``     — ``lax.top_k`` over |x|.  The oracle: every other engine
                  is tested against it.  Right answer below ~1M elements.
* ``sampled``   — DGC-style sampled-threshold estimation
                  (``sparsify.sampled_threshold`` + a sort-free cumsum
                  compaction): estimate the k-th magnitude from a strided
                  subsample, stream-compact the passers into <= 4k
                  candidate slots, exact top-k over only those candidates.
                  No full-width sort ever runs; exact while <= 4k
                  coordinates pass the estimate.
* ``blockwise`` — the Pallas hot path: ``kernels.ops.hierarchical_topk``
                  (per-VMEM-block top-r candidates, no sort, one HBM pass)
                  for selection, ``samomentum_fused`` for the fused
                  accumulate/threshold/rescale pass, ``scatter_apply`` for
                  the support repair.  Exact whenever ``block_r >= k``;
                  with ``block_r < k`` it is the production oversampled
                  approximation.  ``interpret=None`` auto-falls back to
                  Pallas interpret mode off-TPU.

``engine="auto"`` dispatches by tensor size: exact below
``sampled_threshold_above`` elements, sampled at or above it — the knob
``ExchangeConfig.sampled_threshold_above`` threads straight into this.

Exactly one SAMomentum rescale implementation exists in the repo and it is
``samomentum_rescale`` below (the Pallas kernel + its ref.py oracle are the
fused-kernel semantics contract, validated against it in tests).

Wire quantization (TernGrad-style, ``sparsify.quantize_dequantize``)
composes uniformly here: the *outgoing* message values are quantized, the
velocity rescale never sees the quantization error (unbiased-wire design —
the selection itself is error-compensated, the quantizer must not be).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from .sparsify import (
    SparseLeaf,
    quantize_dequantize,
    quantize_segments,
    sampled_threshold,
    topk_select,
)


# ---------------------------------------------------------------------------
# spec + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Everything a call site needs to say about how to compress.

    engine:  "exact" | "sampled" | "blockwise" | "auto"
    quantize: wire quantization mode for message VALUES
              ("none" | "bf16" | "int8" | "tern", see sparsify)
    sampled_threshold_above: auto-dispatch size cutoff — tensors with at
              least this many elements use the sampled engine
    sample_size: subsample size for the sampled threshold estimate
    block_r: per-block candidate count for blockwise (None = k, i.e. exact)
    interpret: run Pallas kernels in interpret mode; None = auto
              (True off-TPU)
    """

    engine: str = "auto"
    quantize: str = "none"
    sampled_threshold_above: int = 1 << 20
    sample_size: int = 65536
    block_r: int | None = None
    interpret: bool | None = None

    @property
    def value_bits(self) -> int:
        return {"none": 32, "bf16": 16, "int8": 8, "tern": 2}[self.quantize]


DEFAULT_SPEC = CompressionSpec()
EXACT_SPEC = CompressionSpec(engine="exact")


@runtime_checkable
class SelectionEngine(Protocol):
    """One way of computing a top-k support.

    select(x, k)        flat (n,) -> SparseLeaf of exactly k entries
    select_rows(x2d, k) (S, n)    -> (vals (S, k), idx (S, k) int32, local
                                      per-row indices)
    """

    name: str

    def select(self, x: jax.Array, k: int) -> SparseLeaf: ...

    def select_rows(self, x2d: jax.Array, k: int): ...


ENGINES: dict[str, type] = {}


def register_engine(cls):
    ENGINES[cls.name] = cls
    return cls


def get_engine(name: str, spec: CompressionSpec = DEFAULT_SPEC
               ) -> SelectionEngine:
    """Instantiate a registered engine, configured from ``spec``."""
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; have {sorted(ENGINES)} + 'auto'")
    return cls.from_spec(spec)


def resolve_engine(spec: CompressionSpec, size: int) -> SelectionEngine:
    """Engine instance for a ``size``-element tensor: auto-dispatch.

    This is where ``sampled_threshold_above`` is honoured: under "auto", a
    tensor with >= that many elements routes to the sampled engine (the
    exact sort would dominate step time), everything smaller stays exact.
    """
    name = spec.engine
    if name == "auto":
        name = "sampled" if size >= spec.sampled_threshold_above else "exact"
    return get_engine(name, spec)


def _interpret(spec: CompressionSpec) -> bool:
    if spec.interpret is not None:
        return spec.interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# the three engines
# ---------------------------------------------------------------------------

@register_engine
@dataclasses.dataclass(frozen=True)
class ExactEngine:
    """``lax.top_k`` over |x| — the semantics oracle."""

    name = "exact"

    @classmethod
    def from_spec(cls, spec: CompressionSpec):
        return cls()

    def select(self, x, k):
        return topk_select(x, k)

    def select_rows(self, x2d, k):
        _, idx = jax.lax.top_k(jnp.abs(x2d), k)
        idx = idx.astype(jnp.int32)
        return jnp.take_along_axis(x2d, idx, axis=1), idx


def _threshold_compact_rows(x2d, thr, k: int, *, cap_factor: int = 4):
    """Exactly-k selection of threshold passers without a full-width sort.

    This is the point of the sampled threshold: the O(n) work is one
    streaming pass (cumsum rank + scatter) that compacts the passers into
    at most ``cap = cap_factor * k`` candidate slots in index order; an
    exact ``top_k`` then runs over only those candidates (k << n sort).
    The selection is exact whenever at most ``cap`` coordinates pass the
    threshold — the estimator targets ~k passers, so the factor-4 cap
    absorbs estimation error; beyond that, surplus passers are dropped in
    index order (the DGC trade — the dropped mass stays error-compensated
    in the caller's velocity/residual).  Exact zeros never pass (guards
    the degenerate thr == 0 case: a subsample that misses every nonzero
    must not ship zeros while starving the real mass).  If fewer than k
    coordinates pass, the spare slots duplicate the strongest candidate
    with value 0: decode-neutral padding that never fabricates support.

    x2d: (S, n); thr: (S, 1).  Returns (vals (S, k), idx (S, k) int32).
    """
    S, n = x2d.shape
    mag = jnp.abs(x2d)
    cap = int(min(n, cap_factor * k))
    mask = (mag >= thr) & (mag > 0.0)
    rank = jnp.cumsum(mask, axis=1) - 1                   # rank among passers
    ok = mask & (rank < cap)
    rows = jnp.arange(S, dtype=jnp.int32)[:, None]
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (S, n))
    slot = jnp.where(ok, rank, cap)                       # cap = spill column
    cidx = jnp.full((S, cap + 1), -1, jnp.int32).at[rows, slot].set(
        jnp.where(ok, cols, -1))[:, :cap]
    valid_c = cidx >= 0
    cvals = jnp.where(
        valid_c,
        jnp.take_along_axis(x2d, jnp.maximum(cidx, 0), axis=1), 0.0)
    # exact top-k over the <= cap candidates (padding ranks below any real
    # candidate); k <= cap always since k <= n
    _, sel = jax.lax.top_k(jnp.where(valid_c, jnp.abs(cvals), -1.0), k)
    idx = jnp.take_along_axis(cidx, sel, axis=1)
    vals = jnp.take_along_axis(cvals, sel, axis=1)
    invalid = idx < 0
    idx = jnp.where(invalid, jnp.maximum(idx[:, :1], 0), idx)
    vals = jnp.where(invalid, 0.0, vals)
    return vals.astype(x2d.dtype), idx.astype(jnp.int32)


@register_engine
@dataclasses.dataclass(frozen=True)
class SampledEngine:
    """DGC sampled-threshold estimation (Lin et al. 2017).

    The k-th |x| is estimated from a ``sample_size`` strided subsample
    (``sparsify.sampled_threshold``), then the passers are compacted to a
    small candidate set and top-k'd WITHOUT a full-tensor sort
    (``_threshold_compact_rows``) — exact while at most ``4k`` coordinates
    pass the estimate, index-order truncated beyond that; shapes stay
    static and the per-element work is one streaming pass.
    """

    name = "sampled"
    sample_size: int = 65536

    @classmethod
    def from_spec(cls, spec: CompressionSpec):
        return cls(sample_size=spec.sample_size)

    def select(self, x, k):
        flat = x.reshape(-1)
        thr = sampled_threshold(flat, k / flat.shape[0],
                                sample_size=self.sample_size)
        vals, idx = _threshold_compact_rows(flat[None], thr.reshape(1, 1), k)
        return SparseLeaf(values=vals[0], indices=idx[0],
                          size=flat.shape[0])

    def select_rows(self, x2d, k):
        n = x2d.shape[1]
        # one estimator implementation (sparsify.sampled_threshold), vmapped
        # per row so flat and row-wise selections can never drift apart
        thr = jax.vmap(lambda row: sampled_threshold(
            row, k / n, sample_size=self.sample_size))(x2d)
        return _threshold_compact_rows(x2d, thr[:, None], k)


@register_engine
@dataclasses.dataclass(frozen=True)
class BlockwiseEngine:
    """Hierarchical Pallas block selection (kernels/block_topk.py).

    Each 1024-element VMEM block emits its local top-``r`` candidates; a
    cheap top-k over the nb*r candidates finishes the selection.  Exact
    whenever r >= k; ``block_r < k`` is the oversampled production
    approximation (same spirit as the sampled threshold — unsent mass
    stays in the SAMomentum velocity either way).
    """

    name = "blockwise"
    block_r: int | None = None
    interpret: bool = True

    @classmethod
    def from_spec(cls, spec: CompressionSpec):
        return cls(block_r=spec.block_r, interpret=_interpret(spec))

    def _plan(self, n: int, k: int) -> int | None:
        """Per-block candidate count ``r`` guaranteeing >= k REAL
        candidates, or None when the hierarchy cannot cover k (k close to
        n — degrade to exact; small-tensor selection is cheap anyway)."""
        from repro.kernels.block_topk import BLOCK

        nb_real = -(-n // BLOCK)           # blocks holding real data
        n_last = n - (nb_real - 1) * BLOCK  # real elems in the last block
        r = min(BLOCK, max(1, k if self.block_r is None else self.block_r,
                           -(-k // nb_real)))
        while r < BLOCK and (nb_real - 1) * r + min(r, n_last) < k:
            r = min(BLOCK, r * 2)
        if (nb_real - 1) * r + min(r, n_last) < k:
            return None
        return r

    def select(self, x, k):
        from repro.kernels import ops

        flat = x.reshape(-1)
        n = flat.shape[0]
        r = self._plan(n, k)
        if r is None:
            return topk_select(flat, k)
        vals, idx = ops.hierarchical_topk(
            flat, k=k, r=r, interpret=self.interpret)
        # _plan guarantees >= k real candidates and hierarchical_topk ranks
        # padding strictly below real ones, so idx < n always holds here;
        # the clamp is belt-and-braces for decode safety
        idx = jnp.minimum(idx, n - 1)
        return SparseLeaf(values=vals, indices=idx.astype(jnp.int32), size=n)

    def select_rows(self, x2d, k):
        from repro.kernels import ops
        import functools

        n = x2d.shape[1]
        r = self._plan(n, k)
        if r is None:
            return ExactEngine().select_rows(x2d, k)
        f = functools.partial(ops.hierarchical_topk, k=k, r=r,
                              interpret=self.interpret)
        vals, idx = jax.vmap(f)(x2d)
        return vals, jnp.minimum(idx, n - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# SAMomentum on top of a selection — THE single rescale implementation
# ---------------------------------------------------------------------------

def velocity_accumulate(u, g, *, momentum: float, lr: float):
    """Paper Eq. (11): u <- m * u + eta * g (dtype follows the velocity)."""
    return momentum * u + lr * g


def samomentum_rescale(uacc, sent_mask, momentum: float):
    """Paper Alg. 3 line 11 — the ONLY SAMomentum rescale in the repo.

    Sent coordinates keep their velocity; unsent are pre-divided by m so
    next step's ``m * u`` decay cancels and the unsent mass telescopes
    (Eq. 13).  ``sent_mask`` must be the support that is ACTUALLY shipped
    (after any bucket overflow), or mass leaks.
    """
    return jnp.where(sent_mask, uacc, uacc / momentum)


def support_mask(indices, size: int):
    """Boolean (size,) mask from a flat index set."""
    return jnp.zeros((size,), bool).at[indices].set(True)


def rows_support_mask(idx, n: int):
    """Boolean (S, n) mask from per-row index sets (S, k)."""
    S = idx.shape[0]
    rows = jnp.arange(S, dtype=jnp.int32)[:, None]
    return jnp.zeros((S, n), bool).at[rows, idx].set(True)


def quantize_leaf(leaf: SparseLeaf, mode: str) -> SparseLeaf:
    """Wire-quantize one message leaf's values (indices untouched)."""
    if mode == "none":
        return leaf
    vq, _ = quantize_dequantize(leaf.values, mode)
    return SparseLeaf(values=vq.astype(leaf.values.dtype),
                      indices=leaf.indices, size=leaf.size)


def _maybe_quantize_rows(vals, mode: str):
    if mode == "none":
        return vals
    vq, _ = quantize_dequantize(vals, mode)
    return vq.astype(vals.dtype)


def select(x, k: int, spec: CompressionSpec = DEFAULT_SPEC) -> SparseLeaf:
    """Top-k of a flat tensor through the dispatched engine (+ wire
    quantization)."""
    flat = x.reshape(-1)
    eng = resolve_engine(spec, int(flat.shape[0]))
    return quantize_leaf(eng.select(flat, k), spec.quantize)


def select_rows(x2d, k: int, spec: CompressionSpec = DEFAULT_SPEC):
    """Per-row top-k through the dispatched engine (+ wire quantization).

    Returns (vals (S, k), idx (S, k) int32 local per-row)."""
    eng = resolve_engine(spec, int(x2d.shape[1]))
    vals, idx = eng.select_rows(x2d, k)
    return _maybe_quantize_rows(vals, spec.quantize), idx


def samomentum_step(u, g, *, momentum: float, lr: float, k: int,
                    spec: CompressionSpec = DEFAULT_SPEC):
    """One SAMomentum step on one tensor: accumulate -> select -> rescale.

    Returns (msg: SparseLeaf over the flattened tensor, u_new shaped like
    ``u``).  The message holds the UNquantized support selection of the
    chosen engine with ``spec.quantize`` applied to its values; ``u_new``
    never sees quantization error.
    """
    eng = resolve_engine(spec, int(u.size))
    if isinstance(eng, BlockwiseEngine):
        msg, u_new = _samomentum_step_blockwise(
            u, g, eng, momentum=momentum, lr=lr, k=k)
    else:
        uacc = velocity_accumulate(u, g, momentum=momentum, lr=lr)
        flat = uacc.reshape(-1)
        msg = eng.select(flat, k)
        mask = support_mask(msg.indices, flat.shape[0])
        u_new = samomentum_rescale(flat, mask, momentum).reshape(u.shape)
    return quantize_leaf(msg, spec.quantize), u_new


def _samomentum_step_blockwise(u, g, eng: BlockwiseEngine, *, momentum, lr,
                               k):
    """The Pallas hot path: all three kernels in one step.

    1. ``hierarchical_topk`` picks the support of the accumulated velocity
       (one HBM pass, no sort),
    2. ``samomentum_fused`` re-walks (u, g) once against the k-th candidate
       magnitude, producing the thresholded dense output and the rescaled
       velocity in a single fused pass,
    3. ``scatter_apply`` repairs the (tie / r<k oversampling) coordinates
       that pass the threshold but are not in the shipped support — they
       must be rescaled like any unsent coordinate or their mass is lost.
    """
    from repro.kernels import ops

    uacc = velocity_accumulate(u, g, momentum=momentum, lr=lr)
    msg = eng.select(uacc.reshape(-1), k)
    thr = jnp.min(jnp.abs(msg.values))
    # uacc is already materialized for the selection above, so feed it back
    # through the fused kernel as both operands with (m, 1 - m):
    # m*uacc + (1-m)*uacc == uacc — the kernel skips the redundant
    # re-accumulate of (u, g) and only thresholds + rescales (by the real
    # momentum) in its single pass
    sent_dense, u_new = ops.samomentum_fused(
        uacc, uacc, thr, momentum=momentum, lr=1.0 - momentum,
        interpret=eng.interpret)
    # extra = thresholded-but-not-shipped coordinates (0 on the support)
    extra = ops.scatter_apply(sent_dense.reshape(-1), msg.indices,
                              -msg.values, interpret=eng.interpret)
    u_new = u_new.reshape(-1) + extra * (1.0 / momentum - 1.0)
    return msg, u_new.reshape(u.shape)


def quantize_arena(msg: SparseLeaf, mode: str, seg) -> SparseLeaf:
    """Wire-quantize a global-index arena message SEGMENT-WISE.

    ``seg`` is the per-tensor entry count (``ParamSpace.ks(density)``): each
    original tensor's slice of the concatenated value vector gets its own
    scale, exactly like the old per-leaf messages — so arena and per-leaf
    paths are bit-equal under every quantize mode.
    """
    if mode == "none":
        return msg
    return SparseLeaf(values=quantize_segments(msg.values, mode, seg),
                      indices=msg.indices, size=msg.size)


def samomentum_step_arena(u, g, space, *, momentum: float, lr: float,
                          ks, spec: CompressionSpec = DEFAULT_SPEC):
    """SAMomentum over a packed arena: per-tensor steps, one global message.

    ``u``/``g`` are ``(space.total,)`` arenas.  Each leaf view runs the
    SAME :func:`samomentum_step` as the per-leaf path (bit-equal across
    every engine, including the fused blockwise Pallas path); per-leaf
    message indices are rebased by the leaf offset and concatenated into
    one global-index SparseLeaf, and the rescaled velocity views
    concatenate back into one arena.
    """
    vals, idxs, new_u = [], [], []
    for off, k, u_view, g_view in zip(
            space.offsets, ks, space.views(u), space.views(g)):
        msg, u_new = samomentum_step(u_view, g_view, momentum=momentum,
                                     lr=lr, k=k, spec=spec)
        vals.append(msg.values)
        idxs.append(msg.indices + jnp.int32(off))
        new_u.append(u_new.reshape(-1))
    return (SparseLeaf(values=jnp.concatenate(vals),
                       indices=jnp.concatenate(idxs), size=space.total),
            jnp.concatenate(new_u))


def samomentum_step_rows(u2d, g2d, *, momentum: float, lr: float, k: int,
                         spec: CompressionSpec = DEFAULT_SPEC):
    """Row-wise SAMomentum step (the mesh hot path's (S, rest) view).

    Returns (vals (S, k), idx (S, k) int32, u_new (S, rest)).  Callers that
    drop entries after selection (bucket overflow) must rescale with their
    own shipped mask instead — see distributed.py's sharded-PS path.
    """
    uacc = velocity_accumulate(u2d, g2d, momentum=momentum, lr=lr)
    vals, idx = select_rows(uacc, k, spec)
    mask = rows_support_mask(idx, uacc.shape[1])
    return vals, idx, samomentum_rescale(uacc, mask, momentum)
