"""Fully-jitted asynchronous PS simulation: one ``lax.scan`` over events.

The python event loop in async_sim.py is flexible (per-event python
callbacks, byte accounting); this runner trades that for speed — the entire
schedule compiles into a single XLA program (worker states stacked on a
leading axis, events dynamically indexed), ~10-50x faster for the
paper-strength benchmark sweeps.  Bit-equivalent to the python loop
(tests/test_scan_runner.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from . import server as ps
from .baselines import Strategy
from .engine import CompressionSpec


def run_async_scan(
    strategy: Strategy,
    grad_fn,
    params0,
    schedule,
    batches,
    *,
    n_workers: int,
    lr: float,
    secondary_density: float | None = None,
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC,
):
    """Run the whole schedule in one jitted scan.

    schedule: (n_events,) int32 worker ids.
    batches:  pytree stacked on a leading n_events axis.
    Returns (final global model, per-event losses).
    """
    sstate0 = ps.init(params0, n_workers)
    wp0 = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_workers,) + p.shape), params0)
    ws0 = jax.tree.map(
        lambda s: jnp.broadcast_to(s[None], (n_workers,) + s.shape),
        strategy.init(params0))

    def event(carry, xs):
        sstate, wp, ws = carry
        k, batch = xs
        params_k = jax.tree.map(lambda x: x[k], wp)
        strat_k = jax.tree.map(lambda x: x[k], ws)
        loss, grads = grad_fn(params_k, batch)
        strat_k, msg = strategy.step(strat_k, grads, lr)
        sstate = ps.receive(sstate, msg)
        sstate, G = ps.send(sstate, k, secondary_density=secondary_density,
                            spec=secondary_spec)
        params_k = ps.apply_to_params(params_k, G)
        wp = jax.tree.map(lambda x, v: x.at[k].set(v), wp, params_k)
        ws = jax.tree.map(lambda x, v: x.at[k].set(v), ws, strat_k)
        return (sstate, wp, ws), loss

    @jax.jit
    def run(sstate0, wp0, ws0, schedule, batches):
        (sstate, _, _), losses = jax.lax.scan(
            event, (sstate0, wp0, ws0),
            (jnp.asarray(schedule, jnp.int32), batches))
        return sstate, losses

    sstate, losses = run(sstate0, wp0, ws0, schedule, batches)
    return ps.global_model(params0, sstate), losses
