"""Fully-jitted asynchronous PS simulation: one ``lax.scan`` over events.

The python event loop in async_sim.py is flexible (per-event python
callbacks); this runner trades that for speed — the entire schedule
compiles into ONE XLA program.  It is built from the SAME four stage
functions as ``AsyncTrainer`` and the cluster runtime
(``async_sim.client_step_fn`` / ``server_step_fn`` / ``ps.send_commit`` /
``ps.apply_update``), with the codec's jitted segment-wise quantizer
(``wire.quantize_message``) between the stages IN-GRAPH — so losses, final
params, and byte accounting reproduce the python loop bit-for-bit
(tests/test_scan_runner.py) while the flat-arena state makes each event a
single fused scatter per stage:

* worker models:   one ``(n_workers, total)`` arena (dynamic row update),
* worker strategy: arena vectors stacked on a leading worker axis,
* server M / v:    ``(total,)`` and ``(n_workers, total)`` arenas.

Byte accounting never leaves the host for sparse messages: frame sizes are
static per ``(mode, seg, total)`` (``wire.frame_bytes_static``), so the
totals are ``n_events * cost``.  Dense messages (ASGD upward, downward
without secondary compression) have data-dependent frames; the scan emits
their per-event nnz as a stacked output and the exact codec formula
(``wire.dense_frame_bytes``) is applied vectorized afterwards — identical
to what ``wire.frame_bytes`` measures event-by-event in the python loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import async_sim
from . import engine as engine_lib
from . import server as ps
from .baselines import Strategy
from .engine import CompressionSpec
from .paramspace import ParamSpace
from .sparsify import SparseLeaf


def run_async_scan(
    strategy: Strategy,
    grad_fn,
    params0,
    schedule,
    batches,
    *,
    n_workers: int,
    lr: float,
    secondary_density: float | None = None,
    secondary_spec: CompressionSpec = engine_lib.EXACT_SPEC,
    recorder=None,
    metrics: bool = False,
):
    """Run the whole schedule in one jitted scan.

    schedule: (n_events,) int32 worker ids.
    batches:  pytree stacked on a leading n_events axis.
    Returns (final global model, History) — the History carries the same
    losses/staleness/byte totals as ``AsyncTrainer.run``.

    ``metrics=True`` threads a ``telemetry.MetricsState`` through the scan
    carry as an optional extra leg (DESIGN.md §11): the fold reads only
    the optimization-barrier-staged stage outputs, so the data-plane op
    sequence — and therefore every loss/param/byte bit — is unchanged.
    With it off, the compiled program is literally the pre-telemetry one.
    ``recorder`` traces the two host phases (build+compile, execute).
    """
    from repro.cluster import wire  # codec quantizer + byte accounting
    from repro import telemetry
    from repro.telemetry import metrics as metrics_lib

    rec = recorder if recorder is not None else telemetry.NULL
    space = ParamSpace.from_tree(params0)
    up_mode = strategy.quantize
    down_mode = secondary_spec.quantize
    up_seg = strategy.message_seg(space)
    down_seg = (space.ks(secondary_density)
                if secondary_density is not None else None)

    sstate0 = ps.init(params0, n_workers)
    theta0 = space.pack(params0)
    wp0 = jnp.broadcast_to(theta0[None], (n_workers, space.total))
    ws0 = jax.tree.map(
        lambda s: jnp.broadcast_to(s[None], (n_workers,) + s.shape),
        strategy.init(params0))

    client_step = async_sim.client_step_fn(strategy, grad_fn, space)
    server_step = async_sim.server_step_fn(secondary_density, secondary_spec)

    def dense_nnz(m):
        if isinstance(m, SparseLeaf):
            return jnp.zeros((), jnp.int32)
        return jnp.count_nonzero(m).astype(jnp.int32)

    def stage(x):
        """Materialization boundary mirroring the python loop's jit-stage
        edges: without it XLA fuses across stages and the scan can drift a
        ulp from the staged runners."""
        if isinstance(x, SparseLeaf):
            vals, idx = jax.lax.optimization_barrier((x.values, x.indices))
            return SparseLeaf(values=vals, indices=idx, size=x.size)
        if isinstance(x, ps.ServerState):
            M, v, t = jax.lax.optimization_barrier((x.M, x.v, x.t))
            return x._replace(M=M, v=v, t=t)
        return jax.lax.optimization_barrier(x)

    def materialize_dense(x):
        """Kernel boundary for a DENSE upward message.

        ``optimization_barrier`` is erased by XLA before fusion, so a bare
        ``lr * g`` message would fuse into the server's ``M - msg`` and
        LLVM would contract it to an FMA — one ulp off the staged runners
        (where the jit edge materializes the message).  A scatter-add into
        zeros is a real kernel XLA neither elides nor contracts across.
        """
        idx = jnp.arange(x.shape[0], dtype=jnp.int32)
        return jnp.zeros_like(x).at[idx].add(x)

    def event(carry, xs):
        if metrics:
            (sstate, wp, ws, ms), (k, stal, batch) = carry, xs
        else:
            (sstate, wp, ws), (k, batch) = carry, xs
        theta_k = stage(wp[k])
        strat_k = jax.tree.map(lambda x: stage(x[k]), ws)
        strat_k, loss, msg = client_step(theta_k, strat_k, stage(batch), lr)
        strat_k, loss = jax.tree.map(stage, strat_k), stage(loss)
        if not isinstance(msg, SparseLeaf):
            msg = materialize_dense(msg)
        msg = stage(wire.quantize_message(stage(msg), up_mode, seg=up_seg))
        sstate, G = server_step(sstate, msg, k)
        sstate, G = stage(sstate), stage(G)
        G = stage(wire.quantize_message(G, down_mode, seg=down_seg))
        sstate = ps.send_commit(sstate, k, G)
        theta_k = stage(ps.apply_update(theta_k, G))
        wp = wp.at[k].set(theta_k)
        ws = jax.tree.map(lambda x, v: x.at[k].set(v), ws, strat_k)
        if metrics:
            # fold the flight-recorder metrics from the ALREADY-staged
            # values — read-only taps, nothing flows back into the data
            # plane, so the staged op sequence (and its bits) is unchanged
            ms = metrics_lib.update(ms, k, stal,
                                    metrics_lib.msg_nnz(msg),
                                    metrics_lib.msg_nnz(G),
                                    metrics_lib.msg_sqnorm(G))
            return (sstate, wp, ws, ms), (loss, dense_nnz(msg),
                                          dense_nnz(G))
        return (sstate, wp, ws), (loss, dense_nnz(msg), dense_nnz(G))

    stal_np = async_sim.staleness_of(schedule, n_workers)

    # ``sstate0`` is built fresh above and returned updated, so its arenas
    # (M and the fleet-sized v buffer) alias the output in place.  wp0/ws0
    # are scan-carry-only (never returned), so donating them could not
    # alias anything — XLA double-buffers scan carries internally.
    if metrics:
        @partial(jax.jit, donate_argnums=(0,))
        def run(sstate0, wp0, ws0, schedule, batches, ms0, stal):
            (sstate, _, _, ms), out = jax.lax.scan(
                event, (sstate0, wp0, ws0, ms0),
                (jnp.asarray(schedule, jnp.int32),
                 jnp.asarray(stal, jnp.int32), batches))
            return sstate, out, ms

        with rec.span("scan/build_and_compile"):
            ms0 = metrics_lib.init(n_workers)
        with rec.span("scan/execute"):
            sstate, (losses, up_nnz, down_nnz), ms = run(
                sstate0, wp0, ws0, schedule, batches, ms0, stal_np)
    else:
        @partial(jax.jit, donate_argnums=(0,))
        def run(sstate0, wp0, ws0, schedule, batches):
            (sstate, _, _), out = jax.lax.scan(
                event, (sstate0, wp0, ws0),
                (jnp.asarray(schedule, jnp.int32), batches))
            return sstate, out

        ms = None
        with rec.span("scan/execute"):
            sstate, (losses, up_nnz, down_nnz) = run(
                sstate0, wp0, ws0, schedule, batches)

    n_events = len(schedule)
    env = wire.ENVELOPE_BYTES

    def total_bytes(seg, mode, nnz):
        if seg is not None:  # static sparse frames: no device data needed
            return n_events * wire.frame_bytes_static(seg, space.total, mode)
        per_event = env + wire.dense_frame_bytes(
            np.asarray(nnz, dtype=np.int64), space.total)
        return int(per_event.sum())

    hist = async_sim.History(
        losses=np.asarray(losses, np.float64),
        worker_ids=np.asarray(schedule),
        staleness=stal_np,
        up_bytes=total_bytes(up_seg, up_mode, up_nnz),
        down_bytes=total_bytes(down_seg, down_mode, down_nnz),
        evals=[],
        metrics=metrics_lib.drain(ms) if ms is not None else None,
    )
    if rec.enabled:
        def per_event(seg, mode, nnz):
            if seg is not None:
                return np.full(n_events,
                               wire.frame_bytes_static(seg, space.total,
                                                       mode))
            return env + wire.dense_frame_bytes(
                np.asarray(nnz, dtype=np.int64), space.total)

        async_sim._record_run_summary(
            rec, "scan", hist, None, None,
            per_event(up_seg, up_mode, up_nnz),
            per_event(down_seg, down_mode, down_nnz))
    return ps.global_model(params0, sstate), hist
