"""Worker-side update strategies: DGS (ours) and the paper's baselines.

Every strategy shares the model-difference transport of server.py (the paper
ports GD and DGC onto the same transport to make them runnable async — §5:
"We implemented an asynchronous version of Gradient Dropping and DGC by
adding model difference based compression as in our DGS").

A strategy owns only the *worker-side* state and the upward message:

    init(params)                 -> state pytree
    step(state, grads, lr)       -> (state', msg)

msg is either a list[SparseLeaf] (sparsified strategies) or a list of flat
dense arrays (ASGD).  The message always includes the learning rate (the
server applies it verbatim: M <- M - decode(msg)).

All top-k selection goes through core/engine.py: every sparse strategy has
an ``engine`` knob ("exact" | "sampled" | "blockwise" | "auto") and a
``quantize`` wire-quantization knob — they compose uniformly instead of
being DGS-only (DESIGN.md §Compression-engine).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from . import samomentum
from .engine import CompressionSpec
from .sparsify import density_to_k


class StrategyState(NamedTuple):
    inner: Any  # strategy-specific pytree


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str = "base"
    sparse: bool = False
    engine: str = "exact"
    quantize: str = "none"

    @property
    def spec(self) -> CompressionSpec:
        """The compression-engine spec this strategy selects with."""
        return CompressionSpec(engine=self.engine, quantize=self.quantize)

    @property
    def value_bits(self) -> int:
        """Wire bits per message value (byte accounting in async_sim)."""
        return self.spec.value_bits

    def init(self, params) -> StrategyState:
        raise NotImplementedError

    def step(self, state: StrategyState, grads, lr: float):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ASGD(Strategy):
    """Vanilla asynchronous SGD: dense eta*grad upward, dense diff downward."""

    name: str = "asgd"
    sparse: bool = False

    def init(self, params):
        return StrategyState(inner=())

    def step(self, state, grads, lr):
        msg = [lr * g.reshape(-1).astype(jnp.float32) for g in jax.tree.leaves(grads)]
        return state, msg


@dataclasses.dataclass(frozen=True)
class GDAsync(Strategy):
    """Gradient Dropping (Aji & Heafield 2017), async port.

    Residual accumulation of raw (lr-scaled) gradients; top-k of the residual
    is sent; the remainder stays local (Alg. 1).  No momentum correction —
    this is the baseline whose convergence the paper shows degrading.
    """

    name: str = "gd_async"
    sparse: bool = True
    density: float = 0.01

    def init(self, params):
        resid = jax.tree.map(
            lambda p: jnp.zeros((int(p.size),), jnp.float32), params
        )
        return StrategyState(inner=resid)

    def step(self, state, grads, lr):
        spec = self.spec
        resid_leaves, treedef = jax.tree.flatten(state.inner)
        g_leaves = jax.tree.leaves(grads)
        msgs, new_resid = [], []
        for r, g in zip(resid_leaves, g_leaves):
            r = r + lr * g.reshape(-1).astype(jnp.float32)
            k = density_to_k(int(r.shape[0]), self.density)
            msg = engine_lib.select(r, k, spec)
            msgs.append(msg)
            new_resid.append(r.at[msg.indices].set(0.0))
        return StrategyState(inner=jax.tree.unflatten(treedef, new_resid)), msgs


class _DGCState(NamedTuple):
    velocity: Any   # momentum-corrected velocity, per-leaf flat
    residual: Any   # accumulated unsent velocity, per-leaf flat


@dataclasses.dataclass(frozen=True)
class DGCAsync(Strategy):
    """Deep Gradient Compression (Lin et al. 2017), async port.

    Momentum correction: velocity u = m*u + lr*g accumulates into a residual
    r += u; top-k of r is sent; *both* u and r are zeroed on sent coordinates
    (momentum factor masking).  Needs two buffers (contrast SAMomentum's one).
    """

    name: str = "dgc_async"
    sparse: bool = True
    density: float = 0.01
    momentum: float = 0.7
    clip_norm: float | None = None

    def init(self, params):
        z = jax.tree.map(lambda p: jnp.zeros((int(p.size),), jnp.float32), params)
        return StrategyState(inner=_DGCState(velocity=z, residual=z))

    def step(self, state, grads, lr):
        spec = self.spec
        u_leaves, treedef = jax.tree.flatten(state.inner.velocity)
        r_leaves = jax.tree.leaves(state.inner.residual)
        g_leaves = jax.tree.leaves(grads)
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in g_leaves)
            )
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            g_leaves = [g * scale for g in g_leaves]
        msgs, new_u, new_r = [], [], []
        for u, r, g in zip(u_leaves, r_leaves, g_leaves):
            u = engine_lib.velocity_accumulate(
                u, g.reshape(-1).astype(jnp.float32),
                momentum=self.momentum, lr=lr)
            r = r + u
            k = density_to_k(int(r.shape[0]), self.density)
            msg = engine_lib.select(r, k, spec)
            msgs.append(msg)
            new_r.append(r.at[msg.indices].set(0.0))
            new_u.append(u.at[msg.indices].set(0.0))  # momentum factor masking
        return (
            StrategyState(
                inner=_DGCState(
                    velocity=jax.tree.unflatten(treedef, new_u),
                    residual=jax.tree.unflatten(treedef, new_r),
                )
            ),
            msgs,
        )


@dataclasses.dataclass(frozen=True)
class DGS(Strategy):
    """Ours: SAMomentum worker (paper Algorithm 3). One buffer, no residual.

    ``quantize`` composes wire quantization with the sparse message — the
    paper's stated future work (TernGrad combination, §Conclusion):
    "none" | "bf16" | "int8" | "tern".  ``engine`` picks the top-k selector
    (core/engine.py registry).
    """

    name: str = "dgs"
    sparse: bool = True
    density: float = 0.01
    momentum: float = 0.7

    def init(self, params):
        return StrategyState(inner=samomentum.init(params))

    def step(self, state, grads, lr):
        msgs, new_sam = samomentum.tree_update(
            state.inner,
            grads,
            momentum=self.momentum,
            lr=lr,
            density=self.density,
            spec=self.spec,
        )
        return StrategyState(inner=new_sam), msgs


@dataclasses.dataclass(frozen=True)
class DGSPlain(Strategy):
    """Paper Algorithm 1: DGS transport without SAMomentum (residual top-k).

    Worker-side identical to GDAsync; kept as a distinct named strategy so
    ablations (SAMomentum on/off) are explicit.
    """

    name: str = "dgs_plain"
    sparse: bool = True
    density: float = 0.01

    def _delegate(self) -> GDAsync:
        return GDAsync(density=self.density, engine=self.engine,
                       quantize=self.quantize)

    def init(self, params):
        return self._delegate().init(params)

    def step(self, state, grads, lr):
        return self._delegate().step(state, grads, lr)


def msgd_step(params, velocity, grads, *, lr: float, momentum: float):
    """Single-node momentum SGD (the paper's MSGD baseline), Eq. (7)."""
    new_v = jax.tree.map(
        lambda u, g: engine_lib.velocity_accumulate(
            u, g, momentum=momentum, lr=lr),
        velocity, grads)
    new_p = jax.tree.map(lambda p, u: p - u, params, new_v)
    return new_p, new_v


STRATEGIES = {
    "asgd": ASGD,
    "gd_async": GDAsync,
    "dgc_async": DGCAsync,
    "dgs": DGS,
    "dgs_plain": DGSPlain,
}


def make_strategy(name: str, **kw) -> Strategy:
    try:
        cls = STRATEGIES[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; have {sorted(STRATEGIES)}")
    return cls(**kw)
