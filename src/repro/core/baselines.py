"""Worker-side update strategies: DGS (ours) and the paper's baselines.

Every strategy shares the model-difference transport of server.py (the paper
ports GD and DGC onto the same transport to make them runnable async — §5:
"We implemented an asynchronous version of Gradient Dropping and DGC by
adding model difference based compression as in our DGS").

A strategy owns only the *worker-side* state and the upward message:

    init(params)                 -> state (arena-shaped pytree)
    step(state, grads, lr)       -> (state', msg)

State and messages live in the flat parameter arena (core/paramspace.py):
``msg`` is either ONE global-index SparseLeaf over the packed arena
(sparsified strategies — per-tensor top-k on offset-sliced views, indices
rebased by leaf offset) or ONE dense flat ``(total,)`` array (ASGD).  The
message always includes the learning rate (the server applies it verbatim:
M <- M - decode(msg)).

All top-k selection goes through core/engine.py: every sparse strategy has
an ``engine`` knob ("exact" | "sampled" | "blockwise" | "auto") and a
``quantize`` wire-quantization knob — they compose uniformly instead of
being DGS-only (DESIGN.md §10 Compression-engine).  ``message_seg`` exposes
the static per-tensor entry counts of the message (the wire codec's arena
frame segmentation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from . import samomentum
from .engine import CompressionSpec
from .paramspace import ParamSpace


class StrategyState(NamedTuple):
    inner: Any  # strategy-specific pytree (arena vectors)


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str = "base"
    sparse: bool = False
    engine: str = "exact"
    quantize: str = "none"

    @property
    def spec(self) -> CompressionSpec:
        """The compression-engine spec this strategy selects with."""
        return CompressionSpec(engine=self.engine, quantize=self.quantize)

    @property
    def value_bits(self) -> int:
        """Wire bits per message value (byte accounting in async_sim)."""
        return self.spec.value_bits

    def message_seg(self, space: ParamSpace) -> tuple[int, ...] | None:
        """Static per-tensor entry counts of the upward message, or None
        for dense messages.  This is the arena wire frame's segmentation
        AND the per-segment quantization boundaries."""
        return None

    def init(self, params) -> StrategyState:
        raise NotImplementedError

    def step(self, state: StrategyState, grads, lr: float):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class _SparseStrategy(Strategy):
    """Shared plumbing for density-parameterized sparse strategies."""

    sparse: bool = True
    density: float = 0.01

    def message_seg(self, space: ParamSpace) -> tuple[int, ...]:
        return space.ks(self.density)


@dataclasses.dataclass(frozen=True)
class ASGD(Strategy):
    """Vanilla asynchronous SGD: dense eta*grad upward, dense diff downward."""

    name: str = "asgd"
    sparse: bool = False

    def init(self, params):
        return StrategyState(inner=())

    def step(self, state, grads, lr):
        space = ParamSpace.from_tree(grads)
        return state, lr * space.pack(grads)


@dataclasses.dataclass(frozen=True)
class GDAsync(_SparseStrategy):
    """Gradient Dropping (Aji & Heafield 2017), async port.

    Residual accumulation of raw (lr-scaled) gradients in one arena buffer;
    per-tensor top-k of the residual is sent; the remainder stays local
    (Alg. 1).  No momentum correction — this is the baseline whose
    convergence the paper shows degrading.
    """

    name: str = "gd_async"

    def init(self, params):
        space = ParamSpace.from_tree(params)
        return StrategyState(inner=jnp.zeros((space.total,), jnp.float32))

    def step(self, state, grads, lr):
        space = ParamSpace.from_tree(grads)
        r = state.inner + lr * space.pack(grads)
        msg = space.select(r, space.ks(self.density), self.spec)
        return StrategyState(inner=r.at[msg.indices].set(0.0)), msg


class _DGCState(NamedTuple):
    velocity: jax.Array   # momentum-corrected velocity arena (total,)
    residual: jax.Array   # accumulated unsent velocity arena (total,)


@dataclasses.dataclass(frozen=True)
class DGCAsync(_SparseStrategy):
    """Deep Gradient Compression (Lin et al. 2017), async port.

    Momentum correction: velocity u = m*u + lr*g accumulates into a residual
    r += u; per-tensor top-k of r is sent; *both* u and r are zeroed on sent
    coordinates (momentum factor masking) with one arena scatter each.
    Needs two buffers (contrast SAMomentum's one).
    """

    name: str = "dgc_async"
    momentum: float = 0.7
    clip_norm: float | None = None

    def init(self, params):
        space = ParamSpace.from_tree(params)
        # two separate allocations: the jitted client stage donates its
        # strategy-state buffers (in-place velocity/residual updates), and
        # donating one buffer twice through aliased leaves is an error
        return StrategyState(inner=_DGCState(
            velocity=jnp.zeros((space.total,), jnp.float32),
            residual=jnp.zeros((space.total,), jnp.float32)))

    def step(self, state, grads, lr):
        space = ParamSpace.from_tree(grads)
        g = space.pack(grads)
        if self.clip_norm is not None:
            # per-leaf partial sums, matching the pre-arena accumulation
            # order bit-for-bit
            gnorm = jnp.sqrt(sum(jnp.sum(v ** 2) for v in space.views(g)))
            g = g * jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
        u = engine_lib.velocity_accumulate(
            state.inner.velocity, g, momentum=self.momentum, lr=lr)
        r = state.inner.residual + u
        msg = space.select(r, space.ks(self.density), self.spec)
        return (
            StrategyState(inner=_DGCState(
                velocity=u.at[msg.indices].set(0.0),  # momentum factor mask
                residual=r.at[msg.indices].set(0.0))),
            msg,
        )


@dataclasses.dataclass(frozen=True)
class DGS(_SparseStrategy):
    """Ours: SAMomentum worker (paper Algorithm 3). One buffer, no residual.

    ``quantize`` composes wire quantization with the sparse message — the
    paper's stated future work (TernGrad combination, §Conclusion):
    "none" | "bf16" | "int8" | "tern".  ``engine`` picks the top-k selector
    (core/engine.py registry).
    """

    name: str = "dgs"
    momentum: float = 0.7

    def init(self, params):
        return StrategyState(inner=samomentum.init(params))

    def step(self, state, grads, lr):
        msg, new_sam = samomentum.tree_update(
            state.inner,
            grads,
            momentum=self.momentum,
            lr=lr,
            density=self.density,
            spec=self.spec,
        )
        return StrategyState(inner=new_sam), msg


@dataclasses.dataclass(frozen=True)
class DGSPlain(_SparseStrategy):
    """Paper Algorithm 1: DGS transport without SAMomentum (residual top-k).

    Worker-side identical to GDAsync; kept as a distinct named strategy so
    ablations (SAMomentum on/off) are explicit.
    """

    name: str = "dgs_plain"

    def _delegate(self) -> GDAsync:
        return GDAsync(density=self.density, engine=self.engine,
                       quantize=self.quantize)

    def init(self, params):
        return self._delegate().init(params)

    def step(self, state, grads, lr):
        return self._delegate().step(state, grads, lr)


def msgd_step(params, velocity, grads, *, lr: float, momentum: float):
    """Single-node momentum SGD (the paper's MSGD baseline), Eq. (7)."""
    new_v = jax.tree.map(
        lambda u, g: engine_lib.velocity_accumulate(
            u, g, momentum=momentum, lr=lr),
        velocity, grads)
    new_p = jax.tree.map(lambda p, u: p - u, params, new_v)
    return new_p, new_v


STRATEGIES = {
    "asgd": ASGD,
    "gd_async": GDAsync,
    "dgc_async": DGCAsync,
    "dgs": DGS,
    "dgs_plain": DGSPlain,
}


def make_strategy(name: str, **kw) -> Strategy:
    try:
        cls = STRATEGIES[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; have {sorted(STRATEGIES)}")
    return cls(**kw)
