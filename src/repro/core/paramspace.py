"""The flat parameter arena: ONE packed buffer behind every DGS data path.

Every layer of the pipeline used to iterate a Python list of per-leaf flat
vectors — one small scatter per leaf per event for server ``M``/``v_k``
bookkeeping, worker apply, and the wire.  Real gradient-compression systems
fuse per-tensor messages into contiguous buckets precisely to kill that
per-tensor dispatch overhead (Deep Gradient Compression; Sparse
Communication for Training Deep Networks).  This module is the descriptor
that makes the fusion possible while keeping the paper's semantics:

* :class:`ParamSpace` — a STATIC descriptor of a parameter pytree: treedef,
  per-leaf shapes/dtypes/sizes and their offsets into one contiguous f32
  arena of ``total`` elements.  Registered as a static pytree node, so it
  can ride inside jitted state (``ServerState.space``) at zero trace cost.
* ``pack``/``unpack`` — pytree <-> ``(total,)`` f32 arena, leaf order =
  ``jax.tree.leaves`` order (offsets are the running sum of leaf sizes).
* ``select`` — paper-faithful PER-TENSOR top-k (Algorithm 1 line 8 selects
  a threshold per parameter tensor ``j``) through the pluggable engine
  registry of :mod:`repro.core.engine`, run on offset-sliced views of the
  arena; the per-leaf indices are REBASED by the leaf offset and the
  per-leaf selections concatenated into one global-index
  :class:`~repro.core.sparsify.SparseLeaf` over the whole arena.  The
  index-rebasing rule: ``global_index = leaf_offset + local_index``; leaf
  ranges are disjoint, so one scatter-add applies every tensor's update.
* ``split`` — the inverse view for tests/inspection: a global arena
  message back into per-leaf ``SparseLeaf``s with local indices.
* :class:`ShardSpec` — a range partition of the arena index space
  ``[0, total)`` into ``S`` contiguous shards (DESIGN.md §12).  The
  rebasing rule is one subtraction: ``shard_local = global - bounds[s]``.
  ``ShardSpec.for_space`` aligns shard boundaries to leaf boundaries, so
  every shard is itself a valid (smaller) parameter arena and the
  per-tensor selection semantics are preserved shard-locally;
  ``ShardSpec.even`` is the equal-stride rule ``core/distributed.py``'s
  shardedps mesh exchange partitions with (``ceil(total / S)`` per
  shard, ``owner = index // stride``).

Selection stays per-tensor (bit-equal to the old per-leaf path, enforced in
tests/test_paramspace.py); only the *bookkeeping* — server receive/commit,
worker apply, the wire frame — is fused into single-buffer operations.
A single flat buffer also shards trivially (contiguous ranges per host),
which per-leaf lists never did — :class:`ShardSpec` is that partition.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_lib
from .engine import CompressionSpec
from .sparsify import SparseLeaf, density_to_k


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Static descriptor of a parameter pytree packed into one f32 arena."""

    treedef: Any                         # jax PyTreeDef (hashable)
    shapes: tuple[tuple[int, ...], ...]  # per-leaf original shapes
    dtypes: tuple[str, ...]              # per-leaf original dtype names
    sizes: tuple[int, ...]               # per-leaf element counts
    offsets: tuple[int, ...]             # per-leaf start offsets in the arena
    total: int                           # arena length == sum(sizes)

    @classmethod
    def from_tree(cls, tree) -> "ParamSpace":
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
        sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1
                      for s in shapes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes[:-1]))
        dtypes = tuple(str(jnp.asarray(l).dtype) for l in leaves)
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes,
                   sizes=sizes, offsets=offsets, total=int(sum(sizes)))

    # -- layout ------------------------------------------------------------

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    def ks(self, density: float) -> tuple[int, ...]:
        """Static per-leaf top-k counts for a density (the paper's per-tensor
        ``R%`` rule) — doubles as the message segmentation ``seg``."""
        return tuple(density_to_k(s, density) for s in self.sizes)

    def views(self, flat: jax.Array) -> list:
        """Per-leaf flat views of the arena (zero-copy slices)."""
        return [jax.lax.slice_in_dim(flat, off, off + size)
                for off, size in zip(self.offsets, self.sizes)]

    # -- pack / unpack -----------------------------------------------------

    def pack(self, tree) -> jax.Array:
        """Pytree -> one contiguous ``(total,)`` f32 arena."""
        leaves = jax.tree.leaves(tree)
        if not leaves:   # an empty shard of a ShardSpec is a valid space
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [jnp.asarray(l).reshape(-1).astype(jnp.float32) for l in leaves])

    def unpack(self, flat: jax.Array):
        """Arena -> pytree with the original shapes and dtypes."""
        out = [v.reshape(shape).astype(dtype)
               for v, shape, dtype in zip(self.views(flat), self.shapes,
                                          self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)

    # -- global-COO selection / splitting ----------------------------------

    def select(self, x: jax.Array, ks, spec: CompressionSpec
               = engine_lib.DEFAULT_SPEC) -> SparseLeaf:
        """Per-tensor top-k of an arena vector, rebased to global indices.

        Each leaf's view goes through the engine registry exactly as the
        per-leaf path did (including per-segment wire quantization from
        ``spec.quantize`` — one scale per TENSOR, not per message, so the
        arithmetic is bit-equal to per-leaf messages); the results
        concatenate into one global-index SparseLeaf over the arena.
        """
        if not self.sizes:   # an empty shard of a ShardSpec is a valid space
            return SparseLeaf(values=jnp.zeros((0,), jnp.float32),
                              indices=jnp.zeros((0,), jnp.int32),
                              size=self.total)
        vals, idxs = [], []
        for off, k, view in zip(self.offsets, ks, self.views(x)):
            leaf = engine_lib.select(view, k, spec)
            vals.append(leaf.values)
            idxs.append(leaf.indices + jnp.int32(off))
        return SparseLeaf(values=jnp.concatenate(vals),
                          indices=jnp.concatenate(idxs), size=self.total)

    def split(self, msg, seg=None) -> list:
        """Arena message -> per-leaf list (local indices) for inspection.

        ``seg`` is the per-leaf entry count of a sparse message (defaults
        to nothing sensible — pass the segmentation the message was built
        with, e.g. ``space.ks(density)``).  Dense arena vectors split into
        per-leaf flat views.
        """
        if not isinstance(msg, SparseLeaf):
            return self.views(msg)
        if seg is None:
            raise ValueError("splitting a sparse arena message needs seg=")
        out, pos = [], 0
        for off, size, k in zip(self.offsets, self.sizes, seg):
            out.append(SparseLeaf(values=msg.values[pos:pos + k],
                                  indices=msg.indices[pos:pos + k]
                                  - jnp.int32(off),
                                  size=size))
            pos += k
        return out


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Range partition of the arena index space ``[0, total)`` into ``S``
    contiguous shards (DESIGN.md §12).

    ``bounds`` has ``S + 1`` ascending entries with ``bounds[0] == 0`` and
    ``bounds[-1] == total``; shard ``s`` owns global indices
    ``[bounds[s], bounds[s+1])`` and rebases them shard-local with ONE
    subtraction: ``local = global - bounds[s]``.  Ranges are disjoint, so
    scatter-adds routed per shard touch disjoint buffers and commute
    bit-exactly with the unsharded single-buffer scatter — the contract
    that makes an ``S``-shard parameter server reproduce the single-server
    run bit-for-bit.

    ``leaf_splits`` (set by :meth:`for_space`) additionally aligns every
    shard boundary to a leaf boundary: shard ``s`` owns whole tensors
    ``leaf_splits[s]:leaf_splits[s+1]``, so each shard is itself a valid
    parameter arena, per-tensor top-k selection restricted to a shard
    equals the slice of the global selection, and segment-wise wire
    quantization scales are unchanged by the split.  The data plane
    (cluster/server sharding) requires this; :meth:`even` — the stride
    rule ``core/distributed.py``'s shardedps mesh exchange uses
    (``owner = index // stride``) — and arbitrary ``bounds`` are supported
    by the generic :meth:`split_by_shard` for tests and index math.
    """

    bounds: tuple[int, ...]
    leaf_splits: tuple[int, ...] | None = None

    def __post_init__(self):
        b = self.bounds
        if len(b) < 2 or b[0] != 0 or any(x > y for x, y in zip(b, b[1:])):
            raise ValueError(f"bad shard bounds {b}")

    # -- construction --------------------------------------------------------

    @staticmethod
    def even_stride(total: int, n_shards: int) -> int:
        """The equal-shard stride ``ceil(total / n_shards)`` — the single
        partition-arithmetic rule shared with ``core/distributed.py``'s
        shardedps exchange (``owner = index // stride``)."""
        return -(-int(total) // int(n_shards))

    @classmethod
    def even(cls, total: int, n_shards: int) -> "ShardSpec":
        """Equal contiguous ranges of ``even_stride`` elements (the last
        shard takes the remainder; shards past ``total`` are empty)."""
        stride = cls.even_stride(total, n_shards) if total else 0
        bounds = tuple(min(s * stride, int(total))
                       for s in range(n_shards)) + (int(total),)
        return cls(bounds=bounds)

    @classmethod
    def for_space(cls, space: ParamSpace, n_shards: int) -> "ShardSpec":
        """Leaf-ALIGNED partition balancing element counts greedily.

        Boundary ``s`` lands on the leaf edge closest to ``total * s / S``
        (never before the previous boundary), so shards stay contiguous in
        leaf order and as size-balanced as whole tensors allow.  Models
        with fewer leaves than shards get empty trailing shards.
        """
        edges = tuple(space.offsets) + (space.total,)   # leaf edges
        splits = [0]
        for s in range(1, n_shards):
            target = space.total * s / n_shards
            j = min(range(splits[-1], len(edges)),
                    key=lambda j: (abs(edges[j] - target), j),
                    default=splits[-1])
            splits.append(max(j, splits[-1]))
        splits.append(space.n_leaves)
        return cls(bounds=tuple(edges[j] for j in splits),
                   leaf_splits=tuple(splits))

    # -- layout ----------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.bounds) - 1

    @property
    def total(self) -> int:
        return self.bounds[-1]

    @property
    def sizes(self) -> tuple[int, ...]:
        """Per-shard element counts (``max(sizes)`` is the peak per-shard
        ``M`` footprint the sharded server scales down with ``S``)."""
        return tuple(b - a for a, b in zip(self.bounds, self.bounds[1:]))

    def owner_of(self, indices):
        """Shard id owning each global index (host-side numpy)."""
        return np.searchsorted(np.asarray(self.bounds),
                               np.asarray(indices), side="right") - 1

    def shard_leaves(self, leaves: list, s: int) -> list:
        """The leaves shard ``s`` owns (leaf-aligned specs only)."""
        if self.leaf_splits is None:
            raise ValueError("shard_leaves needs a leaf-aligned ShardSpec "
                             "(ShardSpec.for_space)")
        return list(leaves[self.leaf_splits[s]:self.leaf_splits[s + 1]])

    def shard_seg(self, seg, s: int) -> tuple[int, ...]:
        """Shard ``s``'s slice of a per-leaf segmentation table
        (leaf-aligned specs only): whole tensors, whole segments."""
        if self.leaf_splits is None:
            raise ValueError("shard_seg needs a leaf-aligned ShardSpec")
        return tuple(seg[self.leaf_splits[s]:self.leaf_splits[s + 1]])

    # -- message routing ---------------------------------------------------

    def split_dense(self, x) -> list:
        """Dense ``(total,)`` arena -> per-shard contiguous slices."""
        return [x[a:b] for a, b in zip(self.bounds, self.bounds[1:])]

    def split_by_shard(self, msg, seg=None) -> list:
        """Route one arena message to shards; indices rebased shard-local.

        Returns ``[(piece, sub_seg), ...]`` — for a dense arena vector,
        ``piece`` is the shard's contiguous slice (``sub_seg`` None); for
        a global-index :class:`SparseLeaf`, ``piece`` is the shard's
        entries with ``indices - bounds[s]`` and ``sub_seg`` its slice of
        the per-tensor segment table.

        Leaf-aligned specs with ``seg`` split by STATIC slicing (message
        entries are grouped in leaf order, so each shard's entries are one
        contiguous run — no host sync, jit-friendly).  Arbitrary bounds
        fall back to a host-side partition by index range, preserving
        entry order within each shard and splitting any straddled segment
        into per-shard sub-counts.  Splitting happens AFTER quantization
        (values are routed verbatim), so the shard pieces decode bit-equal
        to the unsharded message under every wire mode.
        """
        if not isinstance(msg, SparseLeaf):
            return [(piece, None) for piece in self.split_dense(msg)]
        if seg is None:
            raise ValueError("splitting a sparse arena message needs seg=")
        if int(msg.size) != self.total:
            raise ValueError(f"message over a {msg.size}-element arena "
                             f"cannot split with bounds ending at "
                             f"{self.total}")
        if self.leaf_splits is not None:
            cut = np.cumsum((0,) + tuple(seg))
            out = []
            for s in range(self.n_shards):
                a = int(cut[self.leaf_splits[s]])
                b = int(cut[self.leaf_splits[s + 1]])
                out.append((SparseLeaf(
                    values=msg.values[a:b],
                    indices=msg.indices[a:b] - jnp.int32(self.bounds[s]),
                    size=self.bounds[s + 1] - self.bounds[s]),
                    self.shard_seg(seg, s)))
            return out
        # generic bounds: host-side stable partition by owner range
        vals = np.asarray(msg.values)
        idx = np.asarray(msg.indices)
        owner = self.owner_of(idx)
        seg_id = np.repeat(np.arange(len(seg)), tuple(seg))
        out = []
        for s in range(self.n_shards):
            m = owner == s
            sub_seg = tuple(int(c) for c in
                            np.bincount(seg_id[m], minlength=len(seg)))
            out.append((SparseLeaf(
                values=jnp.asarray(vals[m]),
                indices=jnp.asarray((idx[m] - self.bounds[s])
                                    .astype(np.int32)),
                size=self.bounds[s + 1] - self.bounds[s]), sub_seg))
        return out

    def merge(self, pieces):
        """Inverse of :meth:`split_by_shard`: per-shard pieces (shard
        order) -> one global arena message, indices rebased back by
        ``bounds[s]``.  For leaf-aligned splits this reproduces the
        original message bit-for-bit (same entry order); for generic
        bounds the entries are grouped by shard but scatter-equivalent
        (disjoint per-tensor top-k indices are unique, so the dense
        decode is bit-identical)."""
        if not any(isinstance(p, SparseLeaf) for p in pieces):
            return jnp.concatenate([jnp.asarray(p, jnp.float32)
                                    for p in pieces])
        vals = [p.values for p in pieces]
        idxs = [p.indices + jnp.int32(a)
                for p, a in zip(pieces, self.bounds)]
        return SparseLeaf(values=jnp.concatenate(vals),
                          indices=jnp.concatenate(idxs), size=self.total)
