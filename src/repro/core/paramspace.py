"""The flat parameter arena: ONE packed buffer behind every DGS data path.

Every layer of the pipeline used to iterate a Python list of per-leaf flat
vectors — one small scatter per leaf per event for server ``M``/``v_k``
bookkeeping, worker apply, and the wire.  Real gradient-compression systems
fuse per-tensor messages into contiguous buckets precisely to kill that
per-tensor dispatch overhead (Deep Gradient Compression; Sparse
Communication for Training Deep Networks).  This module is the descriptor
that makes the fusion possible while keeping the paper's semantics:

* :class:`ParamSpace` — a STATIC descriptor of a parameter pytree: treedef,
  per-leaf shapes/dtypes/sizes and their offsets into one contiguous f32
  arena of ``total`` elements.  Registered as a static pytree node, so it
  can ride inside jitted state (``ServerState.space``) at zero trace cost.
* ``pack``/``unpack`` — pytree <-> ``(total,)`` f32 arena, leaf order =
  ``jax.tree.leaves`` order (offsets are the running sum of leaf sizes).
* ``select`` — paper-faithful PER-TENSOR top-k (Algorithm 1 line 8 selects
  a threshold per parameter tensor ``j``) through the pluggable engine
  registry of :mod:`repro.core.engine`, run on offset-sliced views of the
  arena; the per-leaf indices are REBASED by the leaf offset and the
  per-leaf selections concatenated into one global-index
  :class:`~repro.core.sparsify.SparseLeaf` over the whole arena.  The
  index-rebasing rule: ``global_index = leaf_offset + local_index``; leaf
  ranges are disjoint, so one scatter-add applies every tensor's update.
* ``split`` — the inverse view for tests/inspection: a global arena
  message back into per-leaf ``SparseLeaf``s with local indices.

Selection stays per-tensor (bit-equal to the old per-leaf path, enforced in
tests/test_paramspace.py); only the *bookkeeping* — server receive/commit,
worker apply, the wire frame — is fused into single-buffer operations.
A single flat buffer also shards trivially (contiguous ranges per host),
which per-leaf lists never did.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_lib
from .engine import CompressionSpec
from .sparsify import SparseLeaf, density_to_k


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Static descriptor of a parameter pytree packed into one f32 arena."""

    treedef: Any                         # jax PyTreeDef (hashable)
    shapes: tuple[tuple[int, ...], ...]  # per-leaf original shapes
    dtypes: tuple[str, ...]              # per-leaf original dtype names
    sizes: tuple[int, ...]               # per-leaf element counts
    offsets: tuple[int, ...]             # per-leaf start offsets in the arena
    total: int                           # arena length == sum(sizes)

    @classmethod
    def from_tree(cls, tree) -> "ParamSpace":
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
        sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1
                      for s in shapes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes[:-1]))
        dtypes = tuple(str(jnp.asarray(l).dtype) for l in leaves)
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes,
                   sizes=sizes, offsets=offsets, total=int(sum(sizes)))

    # -- layout ------------------------------------------------------------

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    def ks(self, density: float) -> tuple[int, ...]:
        """Static per-leaf top-k counts for a density (the paper's per-tensor
        ``R%`` rule) — doubles as the message segmentation ``seg``."""
        return tuple(density_to_k(s, density) for s in self.sizes)

    def views(self, flat: jax.Array) -> list:
        """Per-leaf flat views of the arena (zero-copy slices)."""
        return [jax.lax.slice_in_dim(flat, off, off + size)
                for off, size in zip(self.offsets, self.sizes)]

    # -- pack / unpack -----------------------------------------------------

    def pack(self, tree) -> jax.Array:
        """Pytree -> one contiguous ``(total,)`` f32 arena."""
        leaves = jax.tree.leaves(tree)
        return jnp.concatenate(
            [jnp.asarray(l).reshape(-1).astype(jnp.float32) for l in leaves])

    def unpack(self, flat: jax.Array):
        """Arena -> pytree with the original shapes and dtypes."""
        out = [v.reshape(shape).astype(dtype)
               for v, shape, dtype in zip(self.views(flat), self.shapes,
                                          self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)

    # -- global-COO selection / splitting ----------------------------------

    def select(self, x: jax.Array, ks, spec: CompressionSpec
               = engine_lib.DEFAULT_SPEC) -> SparseLeaf:
        """Per-tensor top-k of an arena vector, rebased to global indices.

        Each leaf's view goes through the engine registry exactly as the
        per-leaf path did (including per-segment wire quantization from
        ``spec.quantize`` — one scale per TENSOR, not per message, so the
        arithmetic is bit-equal to per-leaf messages); the results
        concatenate into one global-index SparseLeaf over the arena.
        """
        vals, idxs = [], []
        for off, k, view in zip(self.offsets, ks, self.views(x)):
            leaf = engine_lib.select(view, k, spec)
            vals.append(leaf.values)
            idxs.append(leaf.indices + jnp.int32(off))
        return SparseLeaf(values=jnp.concatenate(vals),
                          indices=jnp.concatenate(idxs), size=self.total)

    def split(self, msg, seg=None) -> list:
        """Arena message -> per-leaf list (local indices) for inspection.

        ``seg`` is the per-leaf entry count of a sparse message (defaults
        to nothing sensible — pass the segmentation the message was built
        with, e.g. ``space.ks(density)``).  Dense arena vectors split into
        per-leaf flat views.
        """
        if not isinstance(msg, SparseLeaf):
            return self.views(msg)
        if seg is None:
            raise ValueError("splitting a sparse arena message needs seg=")
        out, pos = [], 0
        for off, size, k in zip(self.offsets, self.sizes, seg):
            out.append(SparseLeaf(values=msg.values[pos:pos + k],
                                  indices=msg.indices[pos:pos + k]
                                  - jnp.int32(off),
                                  size=size))
            pos += k
        return out
