"""DGS as a data-parallel gradient-exchange strategy on a TPU mesh.

This is the production-mesh face of the paper (DESIGN.md §3/§4): the
data-parallel axis is the worker fleet; the parameter server is *sharded
across that axis* (each device owns 1/W of the flattened parameter space).
Three exchange modes, all running inside ``jax.shard_map`` with manual
``("pod","data")`` axes and the ``"model"`` axis left to GSPMD:

* ``dense``     — baseline: ``psum`` (the classic all-reduce).  Comm per
                  device ~ 2 * P * bytes.
* ``allgather`` — paper-faithful port: each worker top-k's its SAMomentum
                  velocity and all-gathers (values, indices); every device
                  scatter-adds the union locally.  Comm ~ W * k * 8 bytes.
* ``shardedps`` — TPU-native dual-way form (beyond-paper, §Perf): entries are
                  bucketed by owner shard and exchanged with ``all_to_all``
                  (upward ~ k * overprovision), shard-owners aggregate into
                  their M shard and return the secondary-compressed
                  model-difference shard via all-gather (downward ~ W * k2).
                  With k2 = k/W this is ~3k per device vs allgather's 2Wk —
                  the PS bandwidth asymmetry reproduced on a flat fabric.
                  Dropped-overflow and the unsent remainder accumulate in the
                  persistent (M - v) difference exactly as paper Eq. (6).

All modes consume *per-worker* gradients (computed on the local batch shard)
and return the aggregated global update (mean over workers), plus new
persistent exchange state.

Every selection (upward SAMomentum top-k, per-row hinted top-k, downward
secondary compression) routes through core/engine.py — ``ExchangeConfig``
names the engine ("auto" dispatches exact vs sampled by tensor size via
``sampled_threshold_above``) and the wire quantization mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import engine as engine_lib
from .engine import CompressionSpec
from .paramspace import ShardSpec
from .sparsify import density_to_k


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    mode: str = "dense"            # dense | allgather | shardedps
    density: float = 0.01          # upward top-k density (1 - R%)
    momentum: float = 0.9          # SAMomentum m
    secondary_density: float | None = None  # shardedps downward density;
                                            # default density/W at call site
    bucket_factor: float = 2.0     # all_to_all bucket overprovisioning
    engine: str = "auto"           # compression engine (core/engine.py):
                                   # exact | sampled | blockwise | auto
    quantize: str = "none"         # wire quantization of message values
    sampled_threshold_above: int = 1 << 20  # auto engine: sampled thr for
                                            # leaves/rows at least this big
    wire_dtype: str = "float32"    # collective payload dtype (bf16 halves
                                   # value bytes; §Perf change)

    def spec(self) -> CompressionSpec:
        """The compression-engine spec every selection in this exchange
        uses."""
        return CompressionSpec(
            engine=self.engine,
            quantize=self.quantize,
            sampled_threshold_above=self.sampled_threshold_above,
        )


class ExchangeState(NamedTuple):
    """Persistent per-device exchange state (replicated over model axis)."""

    velocity: Any        # SAMomentum velocity pytree (per-worker, local)
    m_shard: Any         # sharded-PS: accumulated update, own shard only
    v_shard: Any         # sharded-PS: what has been broadcast already
    overflow: Any = ()   # sharded-PS: entries dropped at the W*cap bucket
                         # slot, () when the mode has no buckets — a
                         # read-only tap, never fed back into the data plane


def init_state(params, cfg: ExchangeConfig, n_workers: int) -> ExchangeState:
    vel = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.mode == "shardedps":
        def shard_zeros(p):
            shard = ShardSpec.even_stride(int(p.size), n_workers)
            return jnp.zeros((shard,), jnp.float32)
        m = jax.tree.map(shard_zeros, params)
        v = jax.tree.map(shard_zeros, params)
        ovf = jnp.zeros((), jnp.int32)
    else:
        m = v = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
        ovf = ()
    return ExchangeState(velocity=vel, m_shard=m, v_shard=v, overflow=ovf)


# ---------------------------------------------------------------------------
# dense (psum) baseline
# ---------------------------------------------------------------------------

def dense_exchange(grads, axis_names):
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis_names), grads)


def dense_momentum_exchange(state, grads, *, cfg, lr, axis_names):
    """Classic DP baseline: all-reduce mean grads, heavy-ball momentum."""
    g_mean = dense_exchange(grads, axis_names)
    new_u = jax.tree.map(
        lambda u, g: engine_lib.velocity_accumulate(
            u, g.astype(jnp.float32), momentum=cfg.momentum, lr=lr),
        state.velocity, g_mean)
    return new_u, state._replace(velocity=new_u)


# ---------------------------------------------------------------------------
# model-shard-aware leaf exchange (mesh path)
#
# When a parameter dim is sharded over the (GSPMD-auto) "model" axis, a flat
# per-tensor top-k would force XLA to gather the whole gradient across model
# shards.  Instead we top-k along the UNSHARDED dims, per slice of the
# sharded dim: every step of the selection and the scatter-back is then local
# to the model shard, and only the k-sized (values, indices) tuples move
# across the data axes.  Per-slice thresholds are a structured variant of the
# paper's per-tensor threshold (DESIGN.md §3/§4).
# ---------------------------------------------------------------------------

def _leaf_allgather_hinted(u, g, *, k, shard_axis, momentum, lr, axis_names,
                           n_workers, spec, wire_dtype="float32"):
    """SAMomentum + top-k + sparse all-gather for one leaf.

    Returns (update_to_subtract, new_velocity)."""
    if (shard_axis is None or u.ndim == 1) and u.size < (1 << 24):
        msg, u2 = engine_lib.samomentum_step(
            u, g.astype(jnp.float32), momentum=momentum, lr=lr, k=k,
            spec=spec)
        gvals = jax.lax.all_gather(msg.values, axis_names)   # (W, k)
        gidx = jax.lax.all_gather(msg.indices, axis_names)
        size = int(u.size)
        dense = (jnp.zeros((size,), jnp.float32)
                 .at[gidx.reshape(-1)].add(gvals.reshape(-1)))
        return (dense / n_workers).reshape(u.shape), u2
    # 2D row view: shard_axis first (so selection is local per model shard),
    # then fold further leading dims until each row is small enough for a
    # cheap (and int32-safe) per-row top_k.
    ax = shard_axis if shard_axis is not None else 0
    um = jnp.moveaxis(u, ax, 0)
    gm = jnp.moveaxis(g, ax, 0)
    rows, rest = um.shape[0], int(um.size) // um.shape[0]
    dims = list(um.shape[1:])
    while dims and rest > (1 << 22) and len(dims) > 1:
        rows *= dims.pop(0)
        rest = 1
        for d in dims:
            rest *= d
    S = rows
    u2d = um.reshape(S, -1)
    g2d = gm.reshape(S, -1).astype(jnp.float32)
    rest = u2d.shape[1]
    k_row = max(1, min(rest, -(-k // S)))
    vals, idx, u_new = engine_lib.samomentum_step_rows(
        u2d, g2d, momentum=momentum, lr=lr, k=k_row, spec=spec)
    rows_idx = jnp.arange(S, dtype=jnp.int32)[:, None]
    wdt = jnp.dtype(wire_dtype)
    gvals = jax.lax.all_gather(vals.astype(wdt), axis_names)  # (W, S, k_row)
    gidx = jax.lax.all_gather(idx, axis_names)
    gv = jnp.moveaxis(gvals, 0, 1).reshape(S, -1).astype(jnp.float32)
    gi = jnp.moveaxis(gidx, 0, 1).reshape(S, -1)
    dense = jnp.zeros((S, rest), jnp.float32).at[rows_idx, gi].add(gv)
    upd = jnp.moveaxis((dense / n_workers).reshape(um.shape), 0, ax)
    u_new = jnp.moveaxis(u_new.reshape(um.shape), 0, ax)
    return upd, u_new


# ---------------------------------------------------------------------------
# allgather sparse exchange (paper-faithful port)
# ---------------------------------------------------------------------------

def allgather_exchange(state, grads, *, cfg, lr, axis_names, n_workers,
                       shard_axes=None):
    """Per-leaf: SAMomentum -> top-k -> all_gather sparse -> local scatter.

    Returns (updates, new_state): ``updates`` is the mean lr-scaled update to
    subtract from the (replicated-over-data) parameters.  ``shard_axes`` is
    an optional per-leaf list of model-sharded dim indices (see above).
    """
    spec = cfg.spec()
    u_leaves, treedef = jax.tree.flatten(state.velocity)
    g_leaves = jax.tree.leaves(grads)
    if shard_axes is None:
        shard_axes = [None] * len(u_leaves)
    upd, new_u = [], []
    for u, g, ax in zip(u_leaves, g_leaves, shard_axes):
        k = density_to_k(int(u.size), cfg.density)
        up, u2 = _leaf_allgather_hinted(
            u, g, k=k, shard_axis=ax, momentum=cfg.momentum, lr=lr,
            axis_names=axis_names, n_workers=n_workers, spec=spec,
            wire_dtype=cfg.wire_dtype)
        upd.append(up)
        new_u.append(u2)
    updates = jax.tree.unflatten(treedef, upd)
    return updates, state._replace(velocity=jax.tree.unflatten(treedef, new_u))


def _leaf_shardedps_hinted(u, g, m_sh, v_sh, *, k, shard_axis, cfg, lr,
                           axis_names, n_workers, spec):
    """Row-wise sharded-PS dual-way exchange for one (model-sharded) leaf.

    View: (S, rest) rows with S on the (GSPMD-auto) model axis.  The data
    axis doubles as a sharded parameter server: data-worker w owns columns
    [w*shard_rest, (w+1)*shard_rest) of every row.

    Upward:  per-row top-k entries are bucketed by owner and exchanged with
             ONE all_to_all (~k entries per device instead of W*k).
    Server:  each owner scatter-adds into its M shard and tracks v (what it
             has broadcast); the difference M - v accumulates every unsent
             remainder and bucket-overflow EXACTLY as paper Eq. (6).
    Down:    top-k2 of the difference shard, all-gathered (~W*k2 = k per
             device with the default k2 = k/W).

    Returns (update, u_new, m_new, v_new, overflow): ``overflow`` is the
    scalar int32 count of selected entries dropped at the ``W*cap`` slot
    this step (their mass stays in the velocity — exactness is never lost,
    but the count is the telemetry satellite's visibility into how tight
    ``bucket_factor`` is)."""
    W = n_workers
    S, rest, ax = rows_view(u.shape, shard_axis)
    if ax is None:
        um = u.reshape(1, -1)
        gm = g.reshape(1, -1)
        ax = 0  # round-trip via reshape below is shape-safe
        um_shape = um.shape
    else:
        um = jnp.moveaxis(u, ax, 0)
        gm = jnp.moveaxis(g, ax, 0)
        um_shape = um.shape
    u2d = um.reshape(S, rest)
    g2d = gm.reshape(S, rest).astype(jnp.float32)
    # the mesh PS and the cluster PS share ONE partition rule: this stride
    # is ShardSpec.even(rest, W)'s shard width, and `idx // shard_rest`
    # below is exactly ShardSpec.owner_of for that even spec — so the
    # in-graph sharded exchange and coordinator sharding agree on which
    # worker owns any flat index
    shard_rest = ShardSpec.even_stride(rest, W)
    k_row = max(1, min(rest, -(-k // S)))
    uacc = engine_lib.velocity_accumulate(u2d, g2d, momentum=cfg.momentum,
                                          lr=lr)
    vals, idx = engine_lib.select_rows(uacc, k_row, spec)    # (S, k_row)
    rows_idx = jnp.arange(S, dtype=jnp.int32)[:, None]
    # ---- bucket by owner, per row ----
    owner = idx // shard_rest                                 # (S, k_row)
    cap = max(1, int(round(k_row / W * cfg.bucket_factor)))
    order = jnp.argsort(owner, axis=1)
    owner_s = jnp.take_along_axis(owner, order, axis=1)
    idx_s = jnp.take_along_axis(idx, order, axis=1)
    vals_s = jnp.take_along_axis(vals, order, axis=1)
    first = jax.vmap(
        lambda o: jnp.searchsorted(o, o, side="left"))(owner_s)
    pos = jnp.arange(k_row, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    ok = pos < cap
    slot = jnp.where(ok, owner_s * cap + pos, W * cap)        # (S, k_row)
    buf_v = jnp.zeros((S, W * cap + 1), jnp.float32).at[
        rows_idx, slot].set(jnp.where(ok, vals_s, 0.0))[:, :-1]
    buf_i = jnp.full((S, W * cap + 1), -1, jnp.int32).at[
        rows_idx, slot].set(jnp.where(ok, idx_s % shard_rest, -1))[:, :-1]
    # SAMomentum rescale: only actually-shipped coords keep u (bucket
    # overflow is NOT shipped — its mass must stay in the velocity)
    shipped = jnp.zeros((S, rest + 1), bool).at[
        rows_idx, jnp.where(ok, idx_s, rest)].set(True)[:, :-1]
    u_new = engine_lib.samomentum_rescale(uacc, shipped, cfg.momentum)
    # ---- all_to_all: (S, W, cap) -> (W, S, cap) ----
    wdt = jnp.dtype(cfg.wire_dtype)
    send_v = jnp.moveaxis(buf_v.reshape(S, W, cap), 1, 0)
    send_i = jnp.moveaxis(buf_i.reshape(S, W, cap), 1, 0)
    recv_v = _all_to_all(send_v.astype(wdt), axis_names).astype(
        jnp.float32)                                          # (W, S, cap)
    recv_i = _all_to_all(send_i, axis_names)
    # ---- server shard update: M -= sum of received ----
    ri = jnp.where(recv_i >= 0, recv_i, shard_rest)           # (W, S, cap)
    ri2 = jnp.moveaxis(ri, 0, 1).reshape(S, W * cap)
    rv2 = jnp.moveaxis(recv_v, 0, 1).reshape(S, W * cap)
    m_flat = jnp.concatenate(
        [m_sh.reshape(S, shard_rest), jnp.zeros((S, 1), jnp.float32)],
        axis=1)
    m_flat = m_flat.at[rows_idx, ri2].add(-rv2)
    m_new = m_flat[:, :shard_rest]
    # ---- downward: secondary-compressed difference shard ----
    v2d = v_sh.reshape(S, shard_rest)
    diff = m_new - v2d
    k2 = max(1, min(shard_rest,
                    int(round(k_row / W)) if cfg.secondary_density is None
                    else density_to_k(shard_rest, cfg.secondary_density)))
    dvals, didx = engine_lib.select_rows(diff, k2, spec)      # (S, k2)
    v_new = v2d.at[rows_idx, didx].add(dvals)
    me = _linear_index(
        (axis_names,) if isinstance(axis_names, str) else tuple(axis_names))
    gidx = jax.lax.all_gather(didx + me * shard_rest, axis_names)  # (W,S,k2)
    gvals = jax.lax.all_gather(dvals.astype(wdt), axis_names).astype(
        jnp.float32)
    gi = jnp.moveaxis(gidx, 0, 1).reshape(S, -1)
    gv = jnp.moveaxis(gvals, 0, 1).reshape(S, -1)
    dense = jnp.zeros((S, W * shard_rest), jnp.float32).at[
        rows_idx, gi].add(gv)[:, :rest]
    if shard_axis is None:
        upd = (-dense / W).reshape(u.shape)
        u_new = u_new.reshape(u.shape)
    else:
        upd = jnp.moveaxis((-dense / W).reshape(um_shape), 0, ax)
        u_new = jnp.moveaxis(u_new.reshape(um_shape), 0, ax)
    ovf = jnp.sum(~ok).astype(jnp.int32)
    return upd, u_new, m_new.reshape(-1), v_new.reshape(-1), ovf


def rows_view(shape, shard_axis):
    """(S, rest, ax) row view used by the hinted exchanges and their state
    shapes.  shard_axis None -> single row (per-tensor selection)."""
    size = 1
    for d in shape:
        size *= int(d)
    if shard_axis is None or len(shape) <= 1:
        return 1, size, None
    dims = [int(d) for d in shape]
    lead = dims.pop(shard_axis)
    rows, rest = lead, size // lead
    while dims and rest > (1 << 22) and len(dims) > 1:
        rows *= dims.pop(0)
        rest = 1
        for d in dims:
            rest *= d
    return rows, rest, shard_axis


def shardedps_state_size(shape, shard_axis, n_workers: int) -> int:
    """Per-device M/v shard length for one leaf (row-major layout)."""
    S, rest, _ = rows_view(shape, shard_axis)
    return S * ShardSpec.even_stride(rest, n_workers)


# ---------------------------------------------------------------------------
# sharded-PS all_to_all exchange (TPU-native dual-way DGS)
# ---------------------------------------------------------------------------

def shardedps_exchange(
    state, grads, *, cfg, lr, axis_names, n_workers, shard_axes=None
):
    """Dual-way sparse exchange against a parameter server sharded over the
    data axis — per-leaf dispatch to the row-wise implementation above."""
    spec = cfg.spec()
    u_leaves, treedef = jax.tree.flatten(state.velocity)
    m_leaves = jax.tree.leaves(state.m_shard)
    v_leaves = jax.tree.leaves(state.v_shard)
    g_leaves = jax.tree.leaves(grads)
    if shard_axes is None:
        shard_axes = [None] * len(u_leaves)
    upd, new_u, new_m, new_v = [], [], [], []
    step_ovf = jnp.zeros((), jnp.int32)
    for u, m_sh, v_sh, g, ax in zip(u_leaves, m_leaves, v_leaves, g_leaves,
                                    shard_axes):
        k = density_to_k(int(u.size), cfg.density)
        up, u2, m2, v2, ovf = _leaf_shardedps_hinted(
            u, g, m_sh, v_sh, k=k, shard_axis=ax, cfg=cfg, lr=lr,
            axis_names=axis_names, n_workers=n_workers, spec=spec)
        upd.append(up)
        new_u.append(u2)
        new_m.append(m2)
        new_v.append(v2)
        step_ovf = step_ovf + ovf
    updates = jax.tree.unflatten(treedef, upd)
    # states built by older callers carry the defaulted () — start at zero
    prev = state.overflow
    base = prev if jax.tree_util.tree_leaves(prev) else jnp.zeros(
        (), jnp.int32)
    return updates, ExchangeState(
        velocity=jax.tree.unflatten(treedef, new_u),
        m_shard=jax.tree.unflatten(treedef, new_m),
        v_shard=jax.tree.unflatten(treedef, new_v),
        overflow=base + step_ovf,
    )


def _all_to_all(x, axis_names):
    """all_to_all over possibly-multiple manual axes: (W, c) -> (W, c) where
    row i of the result is the row this device received from device i."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if len(axis_names) == 1:
        return jax.lax.all_to_all(
            x, axis_names[0], split_axis=0, concat_axis=0, tiled=True
        )
    # fold multiple manual axes: gather then slice own column — functionally
    # identical, XLA rewrites to all-to-all when profitable; used only for
    # the (pod, data) multi-pod case.
    W = x.shape[0]
    g = jax.lax.all_gather(x, axis_names)      # (W, W, c)
    me = _linear_index(axis_names)
    return g[:, me, :] if g.ndim == 3 else jnp.take(g, me, axis=1)


def _linear_index(axis_names):
    idx = jnp.zeros((), jnp.int32)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


# ---------------------------------------------------------------------------
# mesh-shard alltoallv: the in-graph exchange behind the cluster's
# `mesh_shards` server stage (DESIGN.md §14)
# ---------------------------------------------------------------------------

def shard_exchange_batch(spec: ShardSpec, indices, values, *,
                         cap: int | None = None,
                         interpret: bool | None = None,
                         use_mesh: bool | None = None):
    """Route a batch of global-index sparse messages to shard-local slots.

    ``indices``/``values``: ``(B, k)`` with int32 global arena indices
    (``-1`` = padding).  Each message is cut into ``S`` even source chunks
    of ``kp = ShardSpec.even_stride(k, S)`` — one per mesh device — each
    chunk is bucketed by ``kernels.ops.route_by_shard_batch`` (the same
    ``owner_of`` partition rule the coordinator sharding uses), and the
    per-(source, destination) buckets are swapped with one alltoallv-style
    ``_all_to_all`` over a ``shards`` mesh axis.  With fewer than S local
    devices the collective degenerates to the bit-identical pure
    permutation ``swapaxes(src, dst)`` — all_to_all IS that permutation,
    so the two paths agree bit-for-bit (pinned in tests/test_shardspec.py).

    Capacity rule: ``cap`` bounds entries per (source chunk, destination
    shard) pair and defaults to ``kp`` — a chunk only holds ``kp`` entries,
    so the default can NEVER overflow; callers passing a tighter ``cap``
    trade slots for a nonzero ``overflow`` count.

    ``use_mesh`` picks the path explicitly (tests pin their bit-equality
    with it); the ``None`` default auto-selects the collective only on a
    non-CPU backend with >= S devices — forced-host CPU "devices" share
    the same cores, so the multi-device program would replicate the
    surrounding stage work S times for zero parallel gain.

    Returns ``(local_idx, vals, overflow)``: ``(B, S, S*cap)`` shard-local
    indices (``-1`` = empty slot) / values, and the scalar int32 count of
    entries dropped by ``cap``.
    """
    from repro.kernels import ops

    S = spec.n_shards
    B, k = indices.shape
    kp = ShardSpec.even_stride(k, S)
    cap = int(cap) if cap is not None else kp
    pad = S * kp - k
    idx3 = jnp.pad(indices.astype(jnp.int32), ((0, 0), (0, pad)),
                   constant_values=-1).reshape(B, S, kp)
    val3 = jnp.pad(values, ((0, 0), (0, pad))).reshape(B, S, kp)
    bounds = jnp.asarray(spec.bounds, jnp.int32)

    if use_mesh is None:
        use_mesh = (S > 1 and len(jax.devices()) >= S
                    and jax.default_backend() != "cpu")
    if use_mesh and S > 1 and len(jax.devices()) >= S:
        # device-mesh leg: each device routes ITS source chunk and the
        # buckets cross the fabric with the native collective
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.devices()[:S]), ("shards",))

        def stage(idx_c, val_c):
            # (B, 1, kp): this device's source chunk of every message
            ri_c, rv_c, ovf = ops.route_by_shard_batch(
                idx_c[:, 0], val_c[:, 0], bounds=bounds, n_shards=S,
                cap=cap, interpret=interpret)        # (B, S_dst, cap)
            send_i = jnp.moveaxis(ri_c, 1, 0).reshape(S, B * cap)
            send_v = jnp.moveaxis(rv_c, 1, 0).reshape(S, B * cap)
            recv_i = _all_to_all(send_i, "shards")   # (S_src, B * cap)
            recv_v = _all_to_all(send_v, "shards")
            ri = jnp.moveaxis(recv_i.reshape(S, B, cap), 1, 0)
            rv = jnp.moveaxis(recv_v.reshape(S, B, cap), 1, 0)
            return (ri.reshape(B, 1, S * cap), rv.reshape(B, 1, S * cap),
                    ovf[None])

        ri, rv, ovf = jax.shard_map(
            stage, mesh=mesh, axis_names={"shards"},
            in_specs=(P(None, "shards"), P(None, "shards")),
            out_specs=(P(None, "shards"), P(None, "shards"), P("shards")),
            check_vma=False)(idx3, val3)
        return ri, rv, jnp.sum(ovf).astype(jnp.int32)

    # single-device fallback: route every chunk, then apply the identical
    # (src, dst) permutation all_to_all performs
    ri, rv, ovf = ops.route_by_shard_batch(
        idx3.reshape(B * S, kp), val3.reshape(B * S, kp), bounds=bounds,
        n_shards=S, cap=cap, interpret=interpret)
    ri = jnp.swapaxes(ri.reshape(B, S, S, cap), 1, 2)
    rv = jnp.swapaxes(rv.reshape(B, S, S, cap), 1, 2)
    return (ri.reshape(B, S, S * cap), rv.reshape(B, S, S * cap),
            ovf.astype(jnp.int32))


# ---------------------------------------------------------------------------
# unified entry point
# ---------------------------------------------------------------------------

def exchange(state, grads, *, cfg: ExchangeConfig, lr, axis_names, n_workers,
             shard_axes=None):
    if cfg.mode == "dense":
        return dense_momentum_exchange(
            state, grads, cfg=cfg, lr=lr, axis_names=axis_names)
    if cfg.mode == "allgather":
        return allgather_exchange(
            state, grads, cfg=cfg, lr=lr, axis_names=axis_names,
            n_workers=n_workers, shard_axes=shard_axes,
        )
    if cfg.mode == "shardedps":
        return shardedps_exchange(
            state, grads, cfg=cfg, lr=lr, axis_names=axis_names,
            n_workers=n_workers, shard_axes=shard_axes,
        )
    raise ValueError(f"unknown exchange mode {cfg.mode!r}")
