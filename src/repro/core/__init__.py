"""repro.core — the paper's contribution: DGS + SAMomentum + async runtime."""
from repro import compat  # noqa: F401  (jax version backfills, side effects)

from . import (async_sim, baselines, distributed, engine, paramspace,
               samomentum, scan_runner, server, sparsify)
from .baselines import ASGD, DGS, DGCAsync, DGSPlain, GDAsync, make_strategy
from .distributed import ExchangeConfig, ExchangeState, exchange, init_state
from .engine import (CompressionSpec, SelectionEngine, get_engine,
                     register_engine, resolve_engine)
from .paramspace import ParamSpace
from .samomentum import SAMomentumState
from .scan_runner import run_async_scan
from .sparsify import (SparseLeaf, density_to_k, quantize_dequantize,
                       topk_select)

__all__ = [
    "async_sim", "baselines", "distributed", "engine", "paramspace",
    "samomentum", "server", "sparsify", "ASGD", "DGS", "DGCAsync",
    "DGSPlain", "GDAsync", "make_strategy", "ExchangeConfig",
    "ExchangeState", "exchange", "init_state", "CompressionSpec",
    "SelectionEngine", "get_engine", "register_engine", "resolve_engine",
    "ParamSpace", "SAMomentumState", "SparseLeaf", "density_to_k",
    "topk_select",
]
