"""Sparse delta-checkpoints: a base arena plus a chain of committed diffs.

A delta-checkpoint directory holds the live model ARENA (DESIGN.md §8) as

* ``base.npy``     — the f32 ``(total,)`` arena at chain start
* ``deltas.bin``   — an append-only log of wire-framed state deltas
* ``manifest.json``— offsets/sizes/versions of every delta, written after
                     each append (temp file + rename, so a torn append
                     leaves the previous manifest valid and the log tail
                     is simply ignored)

Each delta is one :mod:`repro.cluster.wire` DIFF message whose payload the
codec encodes/decodes verbatim:

* a sparse single-segment ARENA frame carrying ``(index, new value)``
  pairs with **assignment** semantics — the entries of the arena that
  changed since the previous checkpoint, at their NEW values.  Restore is
  a scatter-*set*, never an add, so a restored arena is bit-identical to
  the recorded one regardless of where the chain is truncated or
  compacted (no floating-point cancellation can creep in, unlike
  replaying additive diffs onto a moved base).
* a dense frame (the codec's DENSE/DENSE_COO auto-pick) when the changed
  set is large enough that full state is cheaper — semantically a whole-
  arena assignment, which also makes any dense delta a self-contained
  restore point.

The writer picks whichever framing is smaller per append.  ``version`` is
the producer's committed-event count (the cluster coordinator's served
event counter), carried in the DIFF envelope ``seq`` field; restore can
truncate the chain at any version, and :func:`compact` folds a chain
prefix into a new base without touching the bits of later restores.
"""
from __future__ import annotations

import json
import os
import pathlib

import numpy as np

MANIFEST_FILE = "manifest.json"
BASE_FILE = "base.npy"
LOG_FILE = "deltas.bin"
_FORMAT = 1


def _wire():
    # lazy: keep `import repro.checkpoint` free of the cluster package
    from repro.cluster import wire
    return wire


class DeltaCheckpointWriter:
    """Append-only delta-checkpoint chain over a flat f32 arena.

    ``append(arena, version)`` diffs against the previously recorded
    state, writes one wire-framed delta, and updates the manifest; the
    restored chain is bit-identical to every recorded state
    (tests/test_delta_checkpoint.py property suite).
    """

    def __init__(self, path, base, *, version: int = 0,
                 meta: dict | None = None):
        self.path = pathlib.Path(path)
        os.makedirs(self.path, exist_ok=True)
        base = np.ascontiguousarray(np.asarray(base, np.float32).reshape(-1))
        np.save(self.path / BASE_FILE, base)
        self._prev = base.copy()
        self.total = int(base.size)
        self.base_version = int(version)
        self.meta = dict(meta or {})
        self._entries: list[dict] = []
        self._log = open(self.path / LOG_FILE, "wb")
        self._offset = 0
        self._write_manifest()

    # -- appending ---------------------------------------------------------

    def append(self, arena, version: int) -> dict:
        """Record ``arena`` as one committed delta; returns its manifest
        entry (``{"offset", "nbytes", "version", "k"}``)."""
        wire = _wire()
        from repro.core.sparsify import SparseLeaf
        import jax.numpy as jnp

        arena = np.asarray(arena, np.float32).reshape(-1)
        if arena.size != self.total:
            raise ValueError(f"arena size {arena.size} != chain total "
                             f"{self.total}")
        # != misses -0.0 vs +0.0 flips (IEEE ==), which is exactly the
        # equality the restore contract (np.array_equal) is stated in;
        # NaN != NaN is True, so NaN-poisoned entries always re-record.
        changed = np.flatnonzero(arena != self._prev)
        k = int(changed.size)
        sparse_bytes = wire.arena_frame_bytes((k,) if k else (),
                                              self.total, "none")
        dense_bytes = int(wire.dense_frame_bytes(
            int(np.count_nonzero(arena)), self.total))
        seq = int(version) & 0xFFFFFFFF
        if sparse_bytes <= dense_bytes:
            leaf = SparseLeaf(values=jnp.asarray(arena[changed]),
                              indices=jnp.asarray(changed.astype(np.int32)),
                              size=self.total)
            payload, _ = wire.encode_message(
                wire.DIFF, wire.COORDINATOR_ID, seq, [leaf],
                mode="none", seg=(k,) if k else ())
        else:
            payload, _ = wire.encode_message(
                wire.DIFF, wire.COORDINATOR_ID, seq, [arena])
        self._log.write(payload)
        self._log.flush()
        entry = {"offset": self._offset, "nbytes": len(payload),
                 "version": int(version), "k": k}
        self._offset += len(payload)
        self._entries.append(entry)
        self._prev = arena.copy()
        self._write_manifest()
        return entry

    def close(self) -> None:
        if not self._log.closed:
            self._log.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _write_manifest(self):
        manifest = {"format": _FORMAT, "total": self.total,
                    "base_version": self.base_version, "meta": self.meta,
                    "deltas": self._entries}
        tmp = self.path / (MANIFEST_FILE + ".tmp")
        tmp.write_text(json.dumps(manifest, indent=1))
        os.replace(tmp, self.path / MANIFEST_FILE)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def read_manifest(path) -> dict:
    manifest = json.loads((pathlib.Path(path) / MANIFEST_FILE).read_text())
    if manifest.get("format") != _FORMAT:
        raise ValueError(f"unknown delta-checkpoint format "
                         f"{manifest.get('format')!r}")
    return manifest


def _apply_delta(arena: np.ndarray, payload: bytes) -> np.ndarray:
    """Assignment-apply one wire DIFF payload onto ``arena`` (in place)."""
    wire = _wire()
    from repro.core.sparsify import SparseLeaf

    msg = wire.decode_message(payload)
    if msg.type != wire.DIFF or len(msg.leaves) != 1:
        raise ValueError(f"not a delta frame: type={msg.type} "
                         f"n_leaves={len(msg.leaves)}")
    leaf = msg.leaves[0]
    if isinstance(leaf, SparseLeaf):
        arena[np.asarray(leaf.indices)] = np.asarray(leaf.values)
    else:   # dense delta: a whole-arena assignment
        arena[:] = np.asarray(leaf, np.float32)
    return arena


def load_delta_checkpoint(path, *, upto_version: int | None = None,
                          upto: int | None = None):
    """Restore ``(arena, version, meta)`` from a delta-checkpoint dir.

    ``upto`` truncates the chain after the first ``upto`` deltas;
    ``upto_version`` after the last delta with ``version <= upto_version``
    (both: the stricter wins).  The restored arena is bit-identical to the
    producer's arena at that point in the chain.
    """
    p = pathlib.Path(path)
    manifest = read_manifest(p)
    arena = np.load(p / BASE_FILE).astype(np.float32, copy=True)
    if arena.size != manifest["total"]:
        raise ValueError(f"base arena size {arena.size} != manifest total "
                         f"{manifest['total']}")
    version = manifest["base_version"]
    entries = manifest["deltas"]
    if upto is not None:
        entries = entries[:max(0, int(upto))]
    with open(p / LOG_FILE, "rb") as log:
        for e in entries:
            if upto_version is not None and e["version"] > upto_version:
                break
            log.seek(e["offset"])
            payload = log.read(e["nbytes"])
            if len(payload) != e["nbytes"]:
                raise ValueError(f"torn delta at offset {e['offset']}")
            _apply_delta(arena, payload)
            version = e["version"]
    return arena, version, manifest.get("meta", {})


def compact(path, *, upto: int) -> dict:
    """Fold the first ``upto`` deltas into a new base snapshot.

    The chain's tail (deltas past ``upto``) is preserved byte-for-byte,
    so every restore point at or past the compaction boundary is
    bit-identical before and after — assignment semantics make the folded
    base exactly the arena the dropped prefix restored to.  Returns the
    rewritten manifest.
    """
    p = pathlib.Path(path)
    manifest = read_manifest(p)
    upto = max(0, min(int(upto), len(manifest["deltas"])))
    arena, version, meta = load_delta_checkpoint(p, upto=upto)
    tail = manifest["deltas"][upto:]
    with open(p / LOG_FILE, "rb") as log:
        payloads = []
        for e in tail:
            log.seek(e["offset"])
            payloads.append(log.read(e["nbytes"]))
    np.save(p / BASE_FILE, arena)
    offset, entries = 0, []
    with open(p / LOG_FILE, "wb") as log:
        for e, payload in zip(tail, payloads):
            log.write(payload)
            entries.append({**e, "offset": offset})
            offset += e["nbytes"]
    manifest = {"format": _FORMAT, "total": manifest["total"],
                "base_version": version, "meta": meta, "deltas": entries}
    tmp = p / (MANIFEST_FILE + ".tmp")
    tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, p / MANIFEST_FILE)
    return manifest
