from .checkpoint import load_checkpoint, save_checkpoint
from .delta import (DeltaCheckpointWriter, compact, load_delta_checkpoint,
                    read_manifest)

__all__ = ["load_checkpoint", "save_checkpoint", "DeltaCheckpointWriter",
           "load_delta_checkpoint", "read_manifest", "compact"]
