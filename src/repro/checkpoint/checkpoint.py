"""Pytree checkpointing: flat-path .npz + json metadata, restore-in-place.

Dependency-free (numpy only) and structure-validating on restore; suitable
for the CPU validation runs and as the format the launcher writes.  Arrays
are gathered to host before saving (on a real pod this would be a
per-process sharded write; the format keeps one file per save to stay
simple).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "//"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        arr = jnp.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # numpy has no native bf16; store widened (restore casts back
            # to the target structure's dtype)
            arr = arr.astype(jnp.float32)
        out[key] = np.asarray(arr)
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict | None
                    = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten_with_paths(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    meta = {"step": step, "keys": sorted(arrays), "extra": extra or {}}
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f, indent=1)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (validates key set/shapes)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    arrays, _ = _flatten_with_paths(like)
    missing = set(arrays) - set(npz.files)
    extra = set(npz.files) - set(arrays)
    if missing or extra:
        raise ValueError(
            f"checkpoint structure mismatch: missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat:
        key = _SEP.join(_path_str(p) for p in pathk)
        arr = npz[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, leaf.dtype))
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"
