"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--dir DIR]
Prints a markdown table (and CSV rows for benchmarks.run)."""
from __future__ import annotations

import argparse
import json
import os


def load_rows(dirpath: str) -> list[dict]:
    rows = []
    if not os.path.isdir(dirpath):
        return rows
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(".json"):
            with open(os.path.join(dirpath, fn)) as f:
                rows.append(json.load(f))
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute ms | memory ms | collective ms |"
           " dominant | useful-FLOPs | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        peak = r.get("peak_bytes_per_device")
        peak_s = f"{peak/2**30:.2f}" if peak else "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} | {peak_s} |")
    return "\n".join(lines)


def csv_rows(rows: list[dict]) -> list[str]:
    out = []
    for r in rows:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dom_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        out.append(f"{name},{dom_ms*1e3:.1f},"
                   f"dominant={r['dominant']};"
                   f"c={r['compute_s']*1e3:.2f}ms;"
                   f"m={r['memory_s']*1e3:.2f}ms;"
                   f"x={r['collective_s']*1e3:.2f}ms")
    return out


def run(quick: bool = False, dirpath: str = "experiments/dryrun"):
    return csv_rows(load_rows(dirpath))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    print(markdown(load_rows(args.dir)))
