"""Compression ratio and kernel microbenchmarks.

* message bytes vs density (the dual-way compression ratio table)
* us/call for the Pallas kernels (interpret mode — correctness-path timing,
  NOT TPU performance) vs their jnp references.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_strategy
from repro.core.sparsify import dense_bytes, message_bytes
from repro.kernels import ops, ref

from .common import csv_row, mlp_init


def _time(fn, *args, n=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = False):
    rows = []
    params = mlp_init(jax.random.PRNGKey(0), 256, 10, hidden=(512, 512))
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    dense = dense_bytes(params)
    for density in (0.1, 0.01, 0.001):
        s = make_strategy("dgs", density=density)
        st = s.init(params)
        _, msg = s.step(st, grads, lr=0.1)
        b = message_bytes(msg)
        rows.append(csv_row(
            f"compression/density_{density}", 0.0,
            f"msg_bytes={b};dense_bytes={dense};ratio={dense/b:.0f}x"))
    # kernel microbench (interpret mode on CPU)
    n = 1 << 16 if quick else 1 << 20
    u = jax.random.normal(jax.random.PRNGKey(0), (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    thr = jnp.float32(1.0)
    t_kern = _time(lambda: ops.samomentum_fused(u, g, thr, momentum=0.7,
                                                lr=0.1))
    ref_jit = jax.jit(lambda u, g: ref.samomentum_ref(u, g, thr,
                                                      momentum=0.7, lr=0.1))
    t_ref = _time(lambda: ref_jit(u, g))
    rows.append(csv_row("kernel/samomentum_interp", t_kern,
                        f"ref_us={t_ref:.1f};n={n}"))
    k = max(1, n // 100)
    t_hier = _time(lambda: ops.hierarchical_topk(u, k=k, r=32))
    topk_jit = jax.jit(lambda x: jax.lax.top_k(jnp.abs(x), k))
    t_topk = _time(lambda: topk_jit(u))
    rows.append(csv_row("kernel/block_topk_interp", t_hier,
                        f"lax_topk_us={t_topk:.1f};k={k}"))
    rows.extend(run_engines(quick=quick))
    rows.extend(run_quantization(quick=quick))
    return rows


def run_engines(quick: bool = False):
    """Engine-vs-engine SAMomentum step timing through core/engine.py.

    One full accumulate -> select -> rescale step per engine on the same
    tensor (interpret-mode Pallas for blockwise on CPU — correctness-path
    timing, NOT TPU performance; blockwise runs oversampled r=32 as in
    production).
    """
    from repro.core.engine import CompressionSpec, samomentum_step

    rows = []
    n = 1 << 14 if quick else 1 << 18
    k = max(1, n // 100)
    u = jax.random.normal(jax.random.PRNGKey(2), (n,))
    g = jax.random.normal(jax.random.PRNGKey(3), (n,))
    # sample_size must be << n or the sampled row degenerates into an
    # exact full-tensor threshold (quick n is below the 65536 default)
    for spec in (CompressionSpec(engine="exact"),
                 CompressionSpec(engine="sampled", sample_size=max(64, n // 16)),
                 CompressionSpec(engine="blockwise", block_r=32)):
        step = jax.jit(lambda u, g, _s=spec: samomentum_step(
            u, g, momentum=0.7, lr=0.1, k=k, spec=_s))
        t = _time(lambda: step(u, g))
        rows.append(csv_row(f"engine/{spec.engine}", t, f"n={n};k={k}"))
    return rows


def run_quantization(quick: bool = False):
    """DGS + wire quantization (the paper's TernGrad future-work combo)."""
    import numpy as np

    from repro.core import async_sim, make_strategy

    from .common import make_classification_problem, run_strategy
    rows = []
    params0, grad_fn, batch_fn, accuracy = make_classification_problem(
        seed=0, noise=0.8)
    n_events = 200 if quick else 1000
    for q in ("none", "bf16", "int8", "tern"):
        strat = make_strategy("dgs", density=0.05, momentum=0.7, quantize=q)
        tr = async_sim.AsyncTrainer(strat, grad_fn, 4, lr=0.08)
        sched = async_sim.make_schedule(4, n_events, seed=5, hetero=0.6)
        final, _, hist = tr.run(params0, sched,
                                lambda e, k: batch_fn(e, int(k)))
        rows.append(csv_row(
            f"quantize/dgs_{q}", 0.0,
            f"acc={accuracy(final):.4f};up_bytes={hist.up_bytes}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
