"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows.  --full runs paper-strength
event counts (minutes); the default is the quick profile used by CI.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run a single bench (convergence|scalability|lstm|"
                         "bandwidth|compression|roofline)")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_bandwidth, bench_compression, bench_convergence,
                   bench_lstm, bench_scalability, roofline_table)
    benches = {
        "convergence": bench_convergence.run,     # Table I / Fig 1
        "scalability": bench_scalability.run,     # Table III / Fig 2
        "lstm": bench_lstm.run,                   # Table II
        "bandwidth": bench_bandwidth.run,         # Fig 4
        "compression": bench_compression.run,     # dual-way ratio + kernels
        "roofline": roofline_table.run,           # §Roofline (from dry-run)
    }
    if args.only:
        benches = {args.only: benches[args.only]}
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        try:
            for row in fn(quick=quick):
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failed.append((name, e))
            traceback.print_exc()
    from .common import write_bench_artifacts
    for path in write_bench_artifacts():
        print(f"# wrote {path}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {[n for n, _ in failed]}")


if __name__ == "__main__":
    main()
