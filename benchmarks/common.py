"""Shared benchmark plumbing: the paper's model/task stand-ins and the
async-cluster runner wiring.

The paper trains ResNet-18/CIFAR-10 and a 5-layer LSTM/AN4 on a 32-GPU PS
cluster.  At CPU/benchmark scale we substitute: a conv-ish MLP on a
gaussian-blobs classification task (same optimization phenomenology:
momentum matters, staleness hurts) and a 2-layer LSTM on a delayed-copy
task.  Strategy implementations are the real ones from repro.core.
"""
from __future__ import annotations

import json
import pathlib
import platform
import socket
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import async_sim, make_strategy
from repro.data.synthetic import ClassificationTask, SequenceCopyTask

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------- perf artifacts

# bench name -> list of measurement records; benches append via
# record_perf and run.py / --smoke entries flush to BENCH_<name>.json
_PERF: dict[str, list[dict]] = {}

# BENCH_*.json schema: 2 adds schema_version + env provenance (hostname,
# platform, python/jax versions, backend) and optional per-row histograms
SCHEMA_VERSION = 2


def bench_environment() -> dict:
    """Where the numbers came from — enough to judge row comparability."""
    return {
        "hostname": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def git_commit() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=REPO_ROOT, text=True, stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


def record_perf(bench: str, name: str, *, config: dict,
                events_per_sec: float, nbytes: int,
                wall_clock_s: float, hists: dict | None = None,
                extras: dict | None = None) -> None:
    """Book one measurement for the ``BENCH_<bench>.json`` artifact.

    ``config`` is the measurement's full parameterization (model size,
    workers, events, strategy...) so a row is reproducible from the
    artifact alone.  ``hists`` attaches flight-recorder histograms
    (e.g. ``telemetry.metrics.summarize_log2`` of per-event staleness);
    ``extras`` merges arbitrary scalar context into the row.
    """
    row = {
        "name": name,
        "config": config,
        "events_per_sec": round(float(events_per_sec), 3),
        "bytes": int(nbytes),
        "wall_clock_s": round(float(wall_clock_s), 6),
    }
    if extras:
        row.update(extras)
    if hists:
        row["hists"] = hists
    _PERF.setdefault(bench, []).append(row)


def write_bench_artifacts(root: pathlib.Path | None = None) -> list[str]:
    """Flush every recorded bench to ``BENCH_<name>.json`` at the repo
    root (schema v2: commit + environment + measurement rows); returns
    the paths written."""
    root = pathlib.Path(root) if root is not None else REPO_ROOT
    commit = git_commit()
    env = bench_environment()
    written = []
    for bench, rows in sorted(_PERF.items()):
        path = root / f"BENCH_{bench}.json"
        path.write_text(json.dumps(
            {"schema_version": SCHEMA_VERSION, "commit": commit,
             "environment": env, "bench": bench, "rows": rows}, indent=2)
            + "\n")
        written.append(str(path))
    return written


# --------------------------------------------------------------- MLP model

def mlp_init(key, n_features, n_classes, hidden=(64, 64)):
    params = {}
    dims = [n_features, *hidden, n_classes]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp_apply(params, x):
    n = len([k for k in params if k.startswith("w")])
    h = x
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def make_classification_problem(seed=0, n_features=64, n_classes=10,
                                batch_size=32, noise=0.6):
    task = ClassificationTask(n_features=n_features, n_classes=n_classes,
                              batch_size=batch_size, seed=seed, noise=noise)

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            logits = mlp_apply(p, x)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        return task.batch(e, worker=k)

    def accuracy(params):
        x, y = task.eval_set(1024)
        return float(jnp.mean(jnp.argmax(mlp_apply(params, x), -1) == y))

    params0 = mlp_init(jax.random.PRNGKey(seed), n_features, n_classes)
    return params0, grad_fn, batch_fn, accuracy


# -------------------------------------------------------------- LSTM model

def lstm_init(key, vocab, hidden, n_layers=2):
    params = {"embed": jax.random.normal(key, (vocab, hidden)) * 0.1}
    for l in range(n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        params[f"l{l}_wx"] = jax.random.normal(
            k1, (hidden, 4 * hidden)) * (1.0 / hidden) ** 0.5
        params[f"l{l}_wh"] = jax.random.normal(
            k2, (hidden, 4 * hidden)) * (1.0 / hidden) ** 0.5
        params[f"l{l}_b"] = jnp.zeros((4 * hidden,))
    key, k = jax.random.split(key)
    params["head"] = jax.random.normal(k, (hidden, vocab)) * 0.1
    return params


def lstm_apply(params, tokens):
    n_layers = len([k for k in params if k.endswith("_wx")])
    h = params["embed"][tokens]                      # (B, S, H)
    B, S, H = h.shape
    for l in range(n_layers):
        wx, wh, b = (params[f"l{l}_wx"], params[f"l{l}_wh"],
                     params[f"l{l}_b"])

        def cell(carry, x_t):
            hp, cp = carry
            z = x_t @ wx + hp @ wh + b
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * cp + jax.nn.sigmoid(i) * jnp.tanh(g)
            hn = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (hn, c), hn

        init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
        _, hs = jax.lax.scan(cell, init, jnp.moveaxis(h, 1, 0))
        h = jnp.moveaxis(hs, 0, 1)
    return h @ params["head"]


def make_copy_problem(seed=0, vocab=32, hidden=64, copy_len=6, delay=6,
                      batch_size=16):
    task = SequenceCopyTask(vocab_size=vocab, copy_len=copy_len, delay=delay,
                            batch_size=batch_size, seed=seed)

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            logits = lstm_apply(p, x)
            lp = jax.nn.log_softmax(logits)
            mask = y >= 0
            tgt = jnp.where(mask, y, 0)
            nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
            return jnp.sum(nll * mask) / jnp.sum(mask)

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        return task.batch(e, worker=k)

    def error_rate(params):
        """Symbol error rate on the copy positions (the WER stand-in)."""
        x, y = task.batch(999983)
        pred = jnp.argmax(lstm_apply(params, x), -1)
        mask = y >= 0
        wrong = jnp.sum((pred != y) & mask)
        return float(wrong / jnp.sum(mask))

    params0 = lstm_init(jax.random.PRNGKey(seed), vocab, hidden)
    return params0, grad_fn, batch_fn, error_rate


# ---------------------------------------------------------------- running

def run_strategy(name, params0, grad_fn, batch_fn, *, n_workers, n_events,
                 lr, density=0.01, momentum=0.7, seed=0, hetero=0.8,
                 lr_fn=None, secondary_density=None, quantize="none"):
    """Run one strategy on the async cluster; returns (final, hist, dt)."""
    if name == "msgd":
        batches = [batch_fn(e, 0) for e in range(n_events)]
        t0 = time.perf_counter()
        final, losses = async_sim.run_msgd(params0, grad_fn, batches, lr=lr,
                                           momentum=momentum, lr_fn=lr_fn)
        dt = time.perf_counter() - t0
        hist = async_sim.History(losses=losses,
                                 worker_ids=np.zeros(n_events, np.int32),
                                 staleness=np.zeros(n_events, np.int64),
                                 up_bytes=0, down_bytes=0, evals=[])
        return final, hist, dt
    kw = {}
    if name != "asgd":
        kw["density"] = density
        kw["quantize"] = quantize
    if name in ("dgc_async", "dgs"):
        kw["momentum"] = momentum
    strat = make_strategy(name, **kw)
    tr = async_sim.AsyncTrainer(strat, grad_fn, n_workers, lr=lr,
                                secondary_density=secondary_density)
    sched = async_sim.make_schedule(n_workers, n_events, seed=seed,
                                    hetero=hetero)
    t0 = time.perf_counter()
    final, _, hist = tr.run(params0, sched, batch_fn, lr_fn=lr_fn)
    dt = time.perf_counter() - t0
    return final, hist, dt


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
