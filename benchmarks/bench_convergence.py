"""Paper Table I / Fig. 1: convergence of MSGD vs ASGD vs GD-async vs
DGC-async vs DGS at 4 workers, 99%-style sparsity (density knob below).
Reports final eval accuracy per strategy (CSV: name,us_per_event,acc)."""
from __future__ import annotations

from .common import csv_row, make_classification_problem, run_strategy

STRATEGIES = ["msgd", "asgd", "gd_async", "dgc_async", "dgs"]


def run(quick: bool = False):
    n_events = 300 if quick else 1500
    density = 0.01  # the paper's 99% sparsity
    params0, grad_fn, batch_fn, accuracy = make_classification_problem(
        seed=0, noise=1.5, batch_size=8, n_features=32)
    rows, results = [], {}
    for name in STRATEGIES:
        final, hist, dt = run_strategy(
            name, params0, grad_fn, batch_fn, n_workers=4,
            n_events=n_events, lr=0.05, density=density, momentum=0.7,
            seed=1)
        acc = accuracy(final)
        results[name] = acc
        rows.append(csv_row(
            f"table1/{name}", dt / n_events * 1e6,
            f"acc={acc:.4f};up_MB={hist.up_bytes/1e6:.3f};"
            f"down_MB={hist.down_bytes/1e6:.3f}"))
    # paper ordering check (soft): dgs >= dgc >= gd; asgd worst of async
    rows.append(csv_row(
        "table1/ordering_ok", 0.0,
        str(results["dgs"] >= results["gd_async"] - 0.05)))
    return rows


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
