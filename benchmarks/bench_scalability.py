"""Paper Table III: accuracy vs worker count (1..32) for every strategy —
the scalability/generalization experiment.  Also reproduces the paper's
momentum-tuning observation (m: 0.7 -> 0.3 at 32 workers recovers accuracy;
'asynchrony begets momentum')."""
from __future__ import annotations

from .common import csv_row, make_classification_problem, run_strategy

WORKERS = [1, 4, 8, 16, 32]
STRATEGIES = ["asgd", "gd_async", "dgc_async", "dgs"]


def run(quick: bool = False):
    events_per_worker = 60 if quick else 150
    density = 0.01
    rows = []
    params0, grad_fn, batch_fn, accuracy = make_classification_problem(
        seed=0, noise=1.5, batch_size=8, n_features=32)
    # single-node MSGD baseline
    final, _, dt = run_strategy("msgd", params0, grad_fn, batch_fn,
                                n_workers=1,
                                n_events=events_per_worker * 4, lr=0.05)
    base_acc = accuracy(final)
    rows.append(csv_row("table3/msgd_w1", dt / events_per_worker / 4 * 1e6,
                        f"acc={base_acc:.4f}"))
    for w in (WORKERS if not quick else [4, 32]):
        n_events = events_per_worker * max(4, w)
        for name in STRATEGIES:
            final, hist, dt = run_strategy(
                name, params0, grad_fn, batch_fn, n_workers=w,
                n_events=n_events, lr=0.05, density=density, momentum=0.7,
                seed=2)
            acc = accuracy(final)
            rows.append(csv_row(
                f"table3/{name}_w{w}", dt / n_events * 1e6,
                f"acc={acc:.4f};delta={acc-base_acc:+.4f};"
                f"stale={hist.staleness.mean():.1f}"))
    # tuned momentum at 32 workers (paper: 0.7 -> 0.3 improves accuracy)
    w = 32
    for m in (0.7, 0.3):
        final, _, dt = run_strategy(
            "dgs", params0, grad_fn, batch_fn, n_workers=w,
            n_events=events_per_worker * w, lr=0.05, density=density,
            momentum=m, seed=2)
        rows.append(csv_row(f"fig2/dgs_w32_m{m}", 0.0,
                            f"acc={accuracy(final):.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
