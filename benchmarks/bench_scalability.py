"""Paper Table III: accuracy vs worker count (1..32) for every strategy —
the scalability/generalization experiment.  Also reproduces the paper's
momentum-tuning observation (m: 0.7 -> 0.3 at 32 workers recovers accuracy;
'asynchrony begets momentum').

Two runtime rows ride along (DESIGN.md §8):

* ``run_arena`` — the flat-arena data plane (ONE fused scatter per server
  receive/commit/apply) against a faithful reimplementation of the old
  per-leaf event loop (one small scatter per tensor per event) on a >= 1M
  parameter multi-leaf model: the fused loop must win wall-clock.
* ``run_scan`` — the fully-jitted ``lax.scan`` runner vs the python event
  loop on the same schedule (the ``--smoke`` row CI exercises).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_row, make_classification_problem, run_strategy

WORKERS = [1, 4, 8, 16, 32]
STRATEGIES = ["asgd", "gd_async", "dgc_async", "dgs"]


def run(quick: bool = False):
    events_per_worker = 60 if quick else 150
    density = 0.01
    rows = []
    params0, grad_fn, batch_fn, accuracy = make_classification_problem(
        seed=0, noise=1.5, batch_size=8, n_features=32)
    # single-node MSGD baseline
    final, _, dt = run_strategy("msgd", params0, grad_fn, batch_fn,
                                n_workers=1,
                                n_events=events_per_worker * 4, lr=0.05)
    base_acc = accuracy(final)
    rows.append(csv_row("table3/msgd_w1", dt / events_per_worker / 4 * 1e6,
                        f"acc={base_acc:.4f}"))
    for w in (WORKERS if not quick else [4, 32]):
        n_events = events_per_worker * max(4, w)
        for name in STRATEGIES:
            final, hist, dt = run_strategy(
                name, params0, grad_fn, batch_fn, n_workers=w,
                n_events=n_events, lr=0.05, density=density, momentum=0.7,
                seed=2)
            acc = accuracy(final)
            rows.append(csv_row(
                f"table3/{name}_w{w}", dt / n_events * 1e6,
                f"acc={acc:.4f};delta={acc-base_acc:+.4f};"
                f"stale={hist.staleness.mean():.1f}"))
    # tuned momentum at 32 workers (paper: 0.7 -> 0.3 improves accuracy)
    w = 32
    for m in (0.7, 0.3):
        final, _, dt = run_strategy(
            "dgs", params0, grad_fn, batch_fn, n_workers=w,
            n_events=events_per_worker * w, lr=0.05, density=density,
            momentum=m, seed=2)
        rows.append(csv_row(f"fig2/dgs_w32_m{m}", 0.0,
                            f"acc={accuracy(final):.4f}"))
    return rows


def _arena_problem(n_features=256, hidden=(640, 512, 512, 512), density=0.01):
    """A >= 1M parameter multi-leaf model + synthetic sparse arena traffic."""
    from repro.core.paramspace import ParamSpace

    from .common import mlp_init

    params = mlp_init(jax.random.PRNGKey(0), n_features, 10, hidden=hidden)
    space = ParamSpace.from_tree(params)
    ks = space.ks(density)
    rng = np.random.default_rng(0)
    vals, idxs = [], []
    for off, size, k in zip(space.offsets, space.sizes, ks):
        idxs.append(rng.choice(size, k, replace=False).astype(np.int32)
                    + off)
        vals.append(rng.normal(size=k).astype(np.float32))
    return params, space, ks, (jnp.asarray(np.concatenate(vals)),
                               jnp.asarray(np.concatenate(idxs)))


def run_arena(quick: bool = False):
    """Fused single-scatter arena event loop vs the per-leaf baseline.

    Times one full server+worker data-plane event (receive + secondary
    select + commit + apply) with identical traffic through (a) the arena
    runtime (core/server.py: one scatter per stage) and (b) the pre-arena
    per-leaf loop (one scatter per tensor per stage), reconstructed here
    verbatim as the baseline.
    """
    from repro.core import server as ps
    from repro.core import engine as engine_lib
    from repro.core.sparsify import SparseLeaf, density_to_k

    density = 0.01
    params, space, ks, (mvals, midx) = _arena_problem(density=density)
    n_events = 10 if quick else 50
    rows = []

    # ---- fused arena path (donated buffers: in-place event updates) -------
    state = ps.init(params, n_workers=4)
    theta = space.pack(params)
    msg = SparseLeaf(values=mvals, indices=midx, size=space.total)

    def arena_event_fn(state, theta, msg, k):
        state = ps.receive(state, msg)
        G = ps.send_select(state, k, secondary_density=density)
        state = ps.send_commit(state, k, G)
        return state, ps.apply_update(theta, G)

    arena_event = jax.jit(arena_event_fn, donate_argnums=(0, 1))
    state, theta = arena_event(state, theta, msg, jnp.int32(0))  # compile
    jax.block_until_ready(theta)
    t0 = time.perf_counter()
    for e in range(n_events):
        state, theta = arena_event(state, theta, msg, jnp.int32(e % 4))
    jax.block_until_ready(theta)
    dt_arena = (time.perf_counter() - t0) / n_events * 1e6

    # ---- per-leaf baseline (the pre-arena data plane, verbatim) -----------
    leaves = [l.reshape(-1).astype(jnp.float32)
              for l in jax.tree.leaves(params)]
    M0 = tuple(jnp.zeros_like(l) for l in leaves)
    v0 = tuple(jnp.zeros((4, l.shape[0]), l.dtype) for l in leaves)
    th0 = tuple(leaves)
    msgs = [SparseLeaf(values=v, indices=i - off, size=size)
            for v, i, off, size in zip(
                np.split(np.asarray(mvals), np.cumsum(ks)[:-1]),
                np.split(np.asarray(midx), np.cumsum(ks)[:-1]),
                space.offsets, space.sizes)]
    msgs = [SparseLeaf(jnp.asarray(m.values), jnp.asarray(m.indices),
                       m.size) for m in msgs]

    def perleaf_event_fn(M, v, th, msgs, k):
        new_M = tuple(m.at[s.indices].add(-s.values)
                      for m, s in zip(M, msgs))
        G = []
        for m, vl in zip(new_M, v):
            diff = m - vl[k]
            kk = density_to_k(int(diff.shape[0]), density)
            G.append(engine_lib.select(diff, kk, engine_lib.EXACT_SPEC))
        new_v = tuple(vl.at[k, g.indices].add(g.values)
                      for vl, g in zip(v, G))
        new_th = tuple(t.at[g.indices].add(g.values)
                       for t, g in zip(th, G))
        return new_M, new_v, new_th

    perleaf_event = jax.jit(perleaf_event_fn, donate_argnums=(0, 1, 2))
    M, v, th = perleaf_event(M0, v0, th0, msgs, jnp.int32(0))  # compile
    jax.block_until_ready(th)
    t0 = time.perf_counter()
    for e in range(n_events):
        M, v, th = perleaf_event(M, v, th, msgs, jnp.int32(e % 4))
    jax.block_until_ready(th)
    dt_perleaf = (time.perf_counter() - t0) / n_events * 1e6

    speedup = dt_perleaf / dt_arena
    rows.append(csv_row("arena/fused_event", dt_arena,
                        f"n_params={space.total};n_leaves={space.n_leaves}"))
    rows.append(csv_row("arena/perleaf_event", dt_perleaf,
                        f"speedup_fused={speedup:.2f}x"))
    assert space.total >= 1_000_000 and space.n_leaves > 1
    return rows, speedup


def run_scan(quick: bool = False):
    """Scan-runner vs python-loop wall clock on the same schedule (the
    fused hot path CI exercises via --smoke)."""
    from repro.core import async_sim, make_strategy
    from repro.core.scan_runner import run_async_scan

    n_events = 60 if quick else 400
    n_workers = 4
    params0, grad_fn, batch_fn, _ = make_classification_problem(
        seed=0, noise=1.0, batch_size=8, n_features=32)
    sched = async_sim.make_schedule(n_workers, n_events, seed=3, hetero=0.7)
    strat = make_strategy("dgs", density=0.05, momentum=0.7,
                          quantize="int8")
    tr = async_sim.AsyncTrainer(strat, grad_fn, n_workers, lr=0.05,
                                secondary_density=0.05)
    t0 = time.perf_counter()
    _, _, h_py = tr.run(params0, sched, batch_fn)
    dt_py = time.perf_counter() - t0
    batches = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    t0 = time.perf_counter()
    _, h_scan = run_async_scan(
        strat, grad_fn, params0, sched, stacked, n_workers=n_workers,
        lr=0.05, secondary_density=0.05)
    dt_scan = time.perf_counter() - t0
    assert h_scan.up_bytes == h_py.up_bytes      # the parity contract
    assert h_scan.down_bytes == h_py.down_bytes
    assert np.array_equal(h_py.losses, np.asarray(h_scan.losses))
    return [
        csv_row("scan/python_loop", dt_py / n_events * 1e6,
                f"events={n_events}"),
        csv_row("scan/lax_scan", dt_scan / n_events * 1e6,
                f"speedup={dt_py / dt_scan:.1f}x;bytes_bitequal=1"),
    ]


def smoke() -> int:
    """CI entry: exercise the fused arena + scan hot paths, assert the
    arena event loop beats the per-leaf baseline.

    Wall-clock on shared CI runners is noisy (quick mode times only 10
    events), so a sub-1x first measurement gets ONE re-run and the hard
    failure threshold carries a margin; the byte-parity asserts inside
    run_scan stay exact.
    """
    rows, speedup = run_arena(quick=True)
    if speedup <= 1.0:   # timing flake? measure once more
        rows2, speedup = run_arena(quick=True)
        rows += rows2
    rows += run_scan(quick=True)
    print("\n".join(rows))
    if speedup < 0.8:
        print(f"FAIL: fused arena slower than per-leaf ({speedup:.2f}x)")
        return 1
    print(f"{'OK' if speedup > 1.0 else 'WARN (noisy run)'}: "
          f"fused arena event loop {speedup:.2f}x vs per-leaf")
    return 0


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    out = run(quick=True)
    arena_rows, _ = run_arena(quick=True)
    out += arena_rows + run_scan(quick=True)
    print("\n".join(out))
