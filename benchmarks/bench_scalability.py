"""Paper Table III: accuracy vs worker count (1..32) for every strategy —
the scalability/generalization experiment.  Also reproduces the paper's
momentum-tuning observation (m: 0.7 -> 0.3 at 32 workers recovers accuracy;
'asynchrony begets momentum').

Three runtime rows ride along (DESIGN.md §8–9):

* ``run_arena`` — the flat-arena data plane (ONE fused scatter per server
  receive/commit/apply) against a faithful reimplementation of the old
  per-leaf event loop (one small scatter per tensor per event) on a >= 1M
  parameter multi-leaf model: the fused loop must win wall-clock.
* ``run_scan`` — the fully-jitted ``lax.scan`` runner vs the python event
  loop on the same schedule.
* ``run_batched_loop`` — ``AsyncTrainer.run_batched`` (vectorized
  multi-worker steps, one dispatch per stage per batch) vs the serial
  reference on the same schedule, with the bit-for-bit parity asserts
  inline; CI gates on the speedup (the ``--smoke`` row) and the
  measurement lands in ``BENCH_scalability.json``.
* ``run_big`` (``--full`` only) — the 10M-param / 100-worker / 1M-event
  configuration: full-scale schedule generation + batching, and the
  batched-vs-serial data plane timed on a capped slice of the schedule.
* ``run_sharded`` — the range-partitioned parameter-server arena
  (DESIGN.md §12) at S ∈ {1, 2, 4}: per-shard commit loops timed
  independently (the slowest shard is the critical path), with the
  bit-parity assert vs the single server inline; CI gates on the
  S=2 throughput row.
* ``run_mesh_sharded`` — the device-mesh shard servers (DESIGN.md §14)
  at S ∈ {1, 2, 4}: ALL S shard arenas run inside one jitted batched
  stage (alltoallv route + fused per-shard scatters), asserted
  bit-identical to the flat batched server; ``--smoke-mesh`` gates the
  S=4 mesh throughput against the S-thread runtime's concurrent
  per-event shard loops.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry

from .common import (csv_row, make_classification_problem, mlp_apply,
                     mlp_init, record_perf, run_strategy)

WORKERS = [1, 4, 8, 16, 32]
STRATEGIES = ["asgd", "gd_async", "dgc_async", "dgs"]


def run(quick: bool = False):
    events_per_worker = 60 if quick else 150
    density = 0.01
    rows = []
    params0, grad_fn, batch_fn, accuracy = make_classification_problem(
        seed=0, noise=1.5, batch_size=8, n_features=32)
    # single-node MSGD baseline
    final, _, dt = run_strategy("msgd", params0, grad_fn, batch_fn,
                                n_workers=1,
                                n_events=events_per_worker * 4, lr=0.05)
    base_acc = accuracy(final)
    rows.append(csv_row("table3/msgd_w1", dt / events_per_worker / 4 * 1e6,
                        f"acc={base_acc:.4f}"))
    for w in (WORKERS if not quick else [4, 32]):
        n_events = events_per_worker * max(4, w)
        for name in STRATEGIES:
            final, hist, dt = run_strategy(
                name, params0, grad_fn, batch_fn, n_workers=w,
                n_events=n_events, lr=0.05, density=density, momentum=0.7,
                seed=2)
            acc = accuracy(final)
            rows.append(csv_row(
                f"table3/{name}_w{w}", dt / n_events * 1e6,
                f"acc={acc:.4f};delta={acc-base_acc:+.4f};"
                f"stale={hist.staleness.mean():.1f}"))
    # tuned momentum at 32 workers (paper: 0.7 -> 0.3 improves accuracy)
    w = 32
    for m in (0.7, 0.3):
        final, _, dt = run_strategy(
            "dgs", params0, grad_fn, batch_fn, n_workers=w,
            n_events=events_per_worker * w, lr=0.05, density=density,
            momentum=m, seed=2)
        rows.append(csv_row(f"fig2/dgs_w32_m{m}", 0.0,
                            f"acc={accuracy(final):.4f}"))
    batched_rows, _ = run_batched_loop(quick=quick)
    rows += batched_rows
    sharded_rows, _ = run_sharded(quick=quick)
    rows += sharded_rows
    mesh_rows, _ = run_mesh_sharded(quick=quick)
    rows += mesh_rows
    if not quick:
        rows += run_big(quick=False)
    return rows


def _arena_problem(n_features=256, hidden=(640, 512, 512, 512), density=0.01):
    """A >= 1M parameter multi-leaf model + synthetic sparse arena traffic."""
    from repro.core.paramspace import ParamSpace

    from .common import mlp_init

    params = mlp_init(jax.random.PRNGKey(0), n_features, 10, hidden=hidden)
    space = ParamSpace.from_tree(params)
    ks = space.ks(density)
    rng = np.random.default_rng(0)
    vals, idxs = [], []
    for off, size, k in zip(space.offsets, space.sizes, ks):
        idxs.append(rng.choice(size, k, replace=False).astype(np.int32)
                    + off)
        vals.append(rng.normal(size=k).astype(np.float32))
    return params, space, ks, (jnp.asarray(np.concatenate(vals)),
                               jnp.asarray(np.concatenate(idxs)))


def run_arena(quick: bool = False):
    """Fused single-scatter arena event loop vs the per-leaf baseline.

    Times one full server+worker data-plane event (receive + secondary
    select + commit + apply) with identical traffic through (a) the arena
    runtime (core/server.py: one scatter per stage) and (b) the pre-arena
    per-leaf loop (one scatter per tensor per stage), reconstructed here
    verbatim as the baseline.
    """
    from repro.core import server as ps
    from repro.core import engine as engine_lib
    from repro.core.sparsify import SparseLeaf, density_to_k

    density = 0.01
    params, space, ks, (mvals, midx) = _arena_problem(density=density)
    n_events = 10 if quick else 50
    rows = []

    # ---- fused arena path (donated buffers: in-place event updates) -------
    state = ps.init(params, n_workers=4)
    theta = space.pack(params)
    msg = SparseLeaf(values=mvals, indices=midx, size=space.total)

    def arena_event_fn(state, theta, msg, k):
        state = ps.receive(state, msg)
        G = ps.send_select(state, k, secondary_density=density)
        state = ps.send_commit(state, k, G)
        return state, ps.apply_update(theta, G)

    arena_event = jax.jit(arena_event_fn, donate_argnums=(0, 1))
    state, theta = arena_event(state, theta, msg, jnp.int32(0))  # compile
    jax.block_until_ready(theta)
    t0 = time.perf_counter()
    for e in range(n_events):
        state, theta = arena_event(state, theta, msg, jnp.int32(e % 4))
    jax.block_until_ready(theta)
    dt_arena = (time.perf_counter() - t0) / n_events * 1e6

    # ---- per-leaf baseline (the pre-arena data plane, verbatim) -----------
    leaves = [l.reshape(-1).astype(jnp.float32)
              for l in jax.tree.leaves(params)]
    M0 = tuple(jnp.zeros_like(l) for l in leaves)
    v0 = tuple(jnp.zeros((4, l.shape[0]), l.dtype) for l in leaves)
    th0 = tuple(leaves)
    msgs = [SparseLeaf(values=v, indices=i - off, size=size)
            for v, i, off, size in zip(
                np.split(np.asarray(mvals), np.cumsum(ks)[:-1]),
                np.split(np.asarray(midx), np.cumsum(ks)[:-1]),
                space.offsets, space.sizes)]
    msgs = [SparseLeaf(jnp.asarray(m.values), jnp.asarray(m.indices),
                       m.size) for m in msgs]

    def perleaf_event_fn(M, v, th, msgs, k):
        new_M = tuple(m.at[s.indices].add(-s.values)
                      for m, s in zip(M, msgs))
        G = []
        for m, vl in zip(new_M, v):
            diff = m - vl[k]
            kk = density_to_k(int(diff.shape[0]), density)
            G.append(engine_lib.select(diff, kk, engine_lib.EXACT_SPEC))
        new_v = tuple(vl.at[k, g.indices].add(g.values)
                      for vl, g in zip(v, G))
        new_th = tuple(t.at[g.indices].add(g.values)
                       for t, g in zip(th, G))
        return new_M, new_v, new_th

    perleaf_event = jax.jit(perleaf_event_fn, donate_argnums=(0, 1, 2))
    M, v, th = perleaf_event(M0, v0, th0, msgs, jnp.int32(0))  # compile
    jax.block_until_ready(th)
    t0 = time.perf_counter()
    for e in range(n_events):
        M, v, th = perleaf_event(M, v, th, msgs, jnp.int32(e % 4))
    jax.block_until_ready(th)
    dt_perleaf = (time.perf_counter() - t0) / n_events * 1e6

    speedup = dt_perleaf / dt_arena
    rows.append(csv_row("arena/fused_event", dt_arena,
                        f"n_params={space.total};n_leaves={space.n_leaves}"))
    rows.append(csv_row("arena/perleaf_event", dt_perleaf,
                        f"speedup_fused={speedup:.2f}x"))
    assert space.total >= 1_000_000 and space.n_leaves > 1
    return rows, speedup


def run_sharded(quick: bool = False):
    """Sharded parameter-server arena vs the single-server commit path.

    Splits the SAME sparse event traffic across S range-partitioned
    shards (DESIGN.md §12) and times each shard's fused
    receive/select/commit loop independently; the sharded wall-clock
    per event is the max over shards, because in deployment every
    shard is its own coordinator and the slowest one is the critical
    path.  Inline asserts pin the tentpole contract — the S-shard
    final model is bit-identical to the single server's — and each S
    lands a ``record_perf`` row carrying events/sec, the static
    per-shard frame bytes, and the peak shard ``M`` size.  Returns
    ``(rows, throughput_by_S)``.
    """
    from repro.cluster import wire
    from repro.core import server as ps
    from repro.core.paramspace import ShardSpec
    from repro.core.sparsify import SparseLeaf

    density = 0.01
    params, space, ks, (mvals, midx) = _arena_problem(density=density)
    n_events = 10 if quick else 50
    n_workers = 4
    msg = SparseLeaf(values=mvals, indices=midx, size=space.total)
    rows, thru = [], {}
    ref_final = None
    for S in (1, 2, 4):
        spec = ShardSpec.for_space(space, S)
        _, states = ps.init_shards(params, n_workers=n_workers,
                                   n_shards=S, shard_spec=spec)
        pieces = spec.split_by_shard(msg, ks)
        per_bytes = wire.shard_frame_bytes_static(spec, ks, "none")

        def event_fn(state, piece, k):
            state = ps.receive(state, piece)
            G = ps.send_select(state, k, secondary_density=density)
            return ps.send_commit(state, k, G)

        event = jax.jit(event_fn, donate_argnums=(0,))
        dts, new_states = [], []
        for st, (piece, _) in zip(states, pieces):
            st = event(st, piece, jnp.int32(0))  # compile (same k as below)
            jax.block_until_ready(st.M)
            t0 = time.perf_counter()
            for e in range(n_events):
                st = event(st, piece, jnp.int32(e % n_workers))
            jax.block_until_ready(st.M)
            dts.append(time.perf_counter() - t0)
            new_states.append(st)
        dt = max(dts)  # critical path across parallel shard coordinators
        final = ps.global_model_shards(params, new_states)
        if S == 1:
            ref_final = final
        else:  # the tentpole contract: sharding never changes the bits
            assert all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(jax.tree.leaves(final),
                                       jax.tree.leaves(ref_final)))
        thru[S] = n_events / dt
        record_perf(
            "scalability", f"sharded/S{S}",
            config={"n_shards": S, "model_params": int(space.total),
                    "density": density, "n_workers": n_workers,
                    "per_shard_frame_bytes": [int(b) for b in per_bytes],
                    "peak_shard_M_elems": int(max(spec.sizes))},
            events_per_sec=n_events / dt,
            nbytes=sum(per_bytes) * n_events, wall_clock_s=dt)
        rows.append(csv_row(
            f"sharded/S{S}", dt / n_events * 1e6,
            f"peak_shard_M={max(spec.sizes)};bits_equal=1;"
            f"shard_bytes={'/'.join(str(int(b)) for b in per_bytes)}"))
    return rows, thru


def run_mesh_sharded(quick: bool = False):
    """Device-mesh shard servers vs the flat batched server (DESIGN.md §14).

    Runs the SAME batched sparse event traffic through (a) the flat
    single-server batched stages (the reference) and (b) the mesh-sharded
    stages at S ∈ {1, 2, 4} — all S shard arenas inside ONE jitted step,
    upward batches routed through the in-graph alltoallv exchange.  The
    inline asserts pin the tentpole contract: final model AND shipped
    downward messages bit-identical to the flat server, zero route
    overflow.  Uses one JAX device per shard when available
    (``XLA_FLAGS=--xla_force_host_platform_device_count=S`` on CPU),
    otherwise the bit-identical single-device fallback — the artifact
    config records which.  Returns ``(rows, throughput_by_S)``.
    """
    from repro.core import async_sim
    from repro.core import server as ps
    from repro.core.engine import EXACT_SPEC
    from repro.core.paramspace import ShardSpec
    from repro.core.sparsify import SparseLeaf

    density = 0.01
    params, space, ks, (mvals, midx) = _arena_problem(density=density)
    n_steps = 10 if quick else 40
    n_workers = 4
    B = n_workers                                    # distinct worker rows
    ids = jnp.arange(B, dtype=jnp.int32)
    msgs = SparseLeaf(values=jnp.tile(mvals[None], (B, 1)),
                      indices=jnp.tile(midx[None], (B, 1)),
                      size=jnp.full((B,), space.total, jnp.int32))

    # flat single-server reference (the batched data plane CI already
    # gates): run the identical step sequence, keep the final model and
    # the last shipped downward batch for the parity asserts below
    server = async_sim.make_batched_server_step(density, EXACT_SPEC)
    commit = async_sim.make_batched_commit(dense_down=False)
    st = ps.init(params, n_workers=n_workers)
    for _ in range(n_steps):
        st, G, _ = server(st, msgs, ids)
        st = commit(st, ids, G)
    ref_final = ps.global_model(params, st)
    ref_G = jax.tree.map(np.asarray, G)

    rows, thru = [], {}
    for S in (1, 2, 4):
        spec = ShardSpec.for_space(space, S)
        mserver = async_sim.make_mesh_batched_server_step(
            density, EXACT_SPEC)
        mcommit = async_sim.make_mesh_batched_commit(dense_down=False)

        def steps(n):
            mst = ps.init_mesh_shards(params, n_workers=n_workers,
                                      n_shards=S, shard_spec=spec)
            for _ in range(n):
                mst2, G, _ = mserver(mst, msgs, ids)
                mst = mcommit(mst2, ids, G)
            jax.block_until_ready(mst.M)
            return mst, G

        steps(1)                                     # warm / compile
        t0 = time.perf_counter()
        mst, G = steps(n_steps)
        dt = time.perf_counter() - t0
        final = ps.global_model(params, mst)
        # the tentpole contract: mesh sharding never changes the bits —
        # not the model, and not the shipped downward message either
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(final),
                                   jax.tree.leaves(ref_final)))
        assert np.array_equal(np.asarray(G.values), ref_G.values)
        assert np.array_equal(np.asarray(G.indices), ref_G.indices)
        assert int(mst.overflow) == 0
        on_mesh = (S > 1 and len(jax.devices()) >= S
                   and jax.default_backend() != "cpu")
        thru[S] = n_steps * B / dt
        record_perf(
            "scalability", f"mesh_sharded/S{S}",
            config={"n_shards": S, "model_params": int(space.total),
                    "density": density, "n_workers": n_workers,
                    "batch": B, "n_devices": len(jax.devices()),
                    "alltoall_on_mesh": bool(on_mesh),
                    "arena_width": int(mst.M.shape[1])},
            events_per_sec=thru[S], nbytes=0, wall_clock_s=dt)
        rows.append(csv_row(
            f"mesh_sharded/S{S}", dt / (n_steps * B) * 1e6,
            f"devices={len(jax.devices())};on_mesh={int(on_mesh)};"
            f"bits_equal=1;overflow=0"))
    return rows, thru


def _runtime_rate(S: int, rounds: int, *, mesh: bool):
    """Events/sec of a full in-process cluster runtime at S shards: the
    S-thread runtime (``n_shards=S`` — S coordinator threads, S wire
    envelopes per event, client-side split/merge) vs the mesh runtime
    (``mesh_shards=S`` — ONE coordinator, one envelope, in-graph route).
    Same problem, same lockstep schedule, warm run first — the wall
    clock measures the event loops, not compilation."""
    from repro.cluster.runner import run_inprocess
    from repro.core import make_strategy

    params0, grad_fn, batch_fn, _ = make_classification_problem(
        seed=0, noise=1.0, batch_size=8, n_features=32)
    n_workers = 4
    sched = np.tile(np.arange(n_workers), rounds)
    strat = make_strategy("dgs", density=0.05, momentum=0.7,
                          quantize="int8")
    kw = {"mesh_shards": S} if mesh else {"n_shards": S}

    def run():
        return run_inprocess(strat, grad_fn, params0, batch_fn,
                             n_workers=n_workers, schedule=sched, lr=0.05,
                             secondary_density=0.05, **kw)

    run()                                            # warm / compile
    t0 = time.perf_counter()
    run()
    return len(sched) / (time.perf_counter() - t0)


def smoke_mesh() -> int:
    """CI entry for the device-mesh shard servers (DESIGN.md §14).

    Runs ``run_mesh_sharded`` (bit-parity asserts inline), then gates the
    S=4 MESH runtime against the S-thread runtime it replaces — full
    ``run_inprocess`` clusters on the same schedule, so the comparison
    includes everything the tentpole claims to delete: S serial
    coordinator event loops, S wire envelopes per event, and the
    client-side frame split/merge.  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the config
    the parity tests pin the collective path under).  Wall-clock on
    shared CI runners is noisy, so a below-threshold first measurement
    gets ONE re-run; the parity asserts stay exact.  Writes
    ``BENCH_scalability.json`` (the ``mesh_sharded/S*`` rows CI greps).
    """
    from .common import write_bench_artifacts

    rounds = 25
    rows, _ = run_mesh_sharded(quick=True)

    def measure():  # best-of-2 per runtime: robust to lazy-compile spikes
        rt = max(_runtime_rate(4, rounds, mesh=False) for _ in range(2))
        rm = max(_runtime_rate(4, rounds, mesh=True) for _ in range(2))
        return rt, rm

    rate_threads, rate_mesh = measure()
    if rate_mesh < rate_threads:   # timing flake? measure once more
        rate_threads, rate_mesh = measure()
    rows.append(csv_row("mesh_sharded/runtime_S4", 1e6 / rate_mesh,
                        f"thread_runtime_ev_s={rate_threads:.1f};"
                        f"rounds={rounds}"))
    record_perf(
        "scalability", "mesh_sharded/runtime_S4",
        config={"n_shards": 4, "rounds": rounds, "comparator":
                "run_inprocess(n_shards=4)",
                "thread_runtime_events_per_sec": round(rate_threads, 2)},
        events_per_sec=rate_mesh, nbytes=0,
        wall_clock_s=rounds * 4 / rate_mesh)
    print("\n".join(rows))
    for path in write_bench_artifacts():
        print(f"wrote {path}")
    ratio = rate_mesh / rate_threads
    # same noisy-wall-clock policy as smoke(): a real regression (< 0.8x)
    # fails; the 0.8-1.0x band is CI-runner noise and only warns — the
    # bit-parity asserts inside run_mesh_sharded stay exact either way
    if ratio < 0.8:
        print(f"FAIL: mesh runtime below the S-thread runtime at S=4 "
              f"({rate_mesh:.1f} vs {rate_threads:.1f} events/s)")
        return 1
    print(f"{'OK' if ratio >= 1.0 else 'WARN (noisy run)'}: mesh runtime "
          f"{rate_mesh:.1f} events/s vs S-thread {rate_threads:.1f} "
          f"({ratio:.2f}x)")
    return 0


def run_scan(quick: bool = False):
    """Scan-runner vs python-loop wall clock on the same schedule (the
    fused hot path CI exercises via --smoke)."""
    from repro.core import async_sim, make_strategy
    from repro.core.scan_runner import run_async_scan

    n_events = 60 if quick else 400
    n_workers = 4
    params0, grad_fn, batch_fn, _ = make_classification_problem(
        seed=0, noise=1.0, batch_size=8, n_features=32)
    sched = async_sim.make_schedule(n_workers, n_events, seed=3, hetero=0.7)
    strat = make_strategy("dgs", density=0.05, momentum=0.7,
                          quantize="int8")
    tr = async_sim.AsyncTrainer(strat, grad_fn, n_workers, lr=0.05,
                                secondary_density=0.05)
    t0 = time.perf_counter()
    _, _, h_py = tr.run(params0, sched, batch_fn)
    dt_py = time.perf_counter() - t0
    batches = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    t0 = time.perf_counter()
    _, h_scan = run_async_scan(
        strat, grad_fn, params0, sched, stacked, n_workers=n_workers,
        lr=0.05, secondary_density=0.05)
    dt_scan = time.perf_counter() - t0
    assert h_scan.up_bytes == h_py.up_bytes      # the parity contract
    assert h_scan.down_bytes == h_py.down_bytes
    assert np.array_equal(h_py.losses, np.asarray(h_scan.losses))
    return [
        csv_row("scan/python_loop", dt_py / n_events * 1e6,
                f"events={n_events}"),
        csv_row("scan/lax_scan", dt_scan / n_events * 1e6,
                f"speedup={dt_py / dt_scan:.1f}x;bytes_bitequal=1"),
    ]


def run_batched_loop(quick: bool = False):
    """Batched event loop vs the serial reference — same schedule, same
    bits, fewer dispatches.

    Both loops warm first (compiles every stage and batch-width
    specialization), then run timed on the full schedule.  The parity
    asserts are the tentpole contract: identical losses, final params,
    and byte totals.  Returns ``(rows, speedup)``.
    """
    from repro.core import async_sim, make_strategy

    n_workers = 32
    n_events = 240 if quick else 1500
    params0, grad_fn, batch_fn, _ = make_classification_problem(
        seed=0, noise=1.0, batch_size=8, n_features=32)
    # moderate heterogeneity: stragglers exist but distinct-worker runs
    # stay long enough (mean batch ~4-5) for the batching to bite
    sched = async_sim.make_schedule(n_workers, n_events, seed=5, hetero=0.4)
    strat = make_strategy("dgs", density=0.05, momentum=0.7,
                          quantize="int8")
    tr = async_sim.AsyncTrainer(strat, grad_fn, n_workers, lr=0.05,
                                secondary_density=0.05)

    # pre-generate the event batches: both loops consume the identical
    # pool, and the timing then measures the event loops rather than the
    # synthetic task's eager batch construction
    pool = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    pooled_fn = lambda e, k: pool[e]  # noqa: E731

    tr.run(params0, sched, pooled_fn)            # warm: serial stages
    tr.run_batched(params0, sched, pooled_fn)    # warm: every batch width
    t0 = time.perf_counter()
    f_s, _, h_s = tr.run(params0, sched, pooled_fn)
    dt_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    f_b, _, h_b = tr.run_batched(params0, sched, pooled_fn)
    dt_batched = time.perf_counter() - t0

    assert np.array_equal(h_s.losses, h_b.losses)         # parity contract
    assert h_s.up_bytes == h_b.up_bytes
    assert h_s.down_bytes == h_b.down_bytes
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(f_s), jax.tree.leaves(f_b)))

    speedup = dt_serial / dt_batched
    config = {"model": "mlp_32f", "strategy": "dgs", "density": 0.05,
              "quantize": "int8", "secondary_density": 0.05,
              "n_workers": n_workers, "n_events": n_events}
    nbytes = h_b.up_bytes + h_b.down_bytes
    # schema-v2 rows carry the run's staleness distribution so the
    # artifact shows WHAT schedule shape produced the throughput number
    hists = {"staleness": telemetry.metrics.summarize_log2(h_b.staleness)}
    record_perf("scalability", "serial_loop", config=config,
                events_per_sec=n_events / dt_serial, nbytes=nbytes,
                wall_clock_s=dt_serial, hists=hists)
    record_perf("scalability", "batched_loop", config=config,
                events_per_sec=n_events / dt_batched, nbytes=nbytes,
                wall_clock_s=dt_batched, hists=hists)
    rows = [
        csv_row("batched/serial_loop", dt_serial / n_events * 1e6,
                f"events={n_events}"),
        csv_row("batched/batched_loop", dt_batched / n_events * 1e6,
                f"speedup={speedup:.2f}x;bits_equal=1"),
    ]
    return rows, speedup


def run_big(quick: bool = False):
    """The full-scale configuration: 10M params, 100 workers, 1M events.

    Schedule generation and event batching run at FULL scale (they are
    host-side and cheap); the jitted data plane is timed on a capped
    slice of the same schedule — 1M events of a 10.5M-param model on one
    CPU core would take hours without telling us anything new about
    dispatch behavior.  The cap is reported in the artifact config, not
    silently dropped.
    """
    from repro.core import async_sim, make_strategy
    from repro.core.paramspace import ParamSpace
    from repro.data.synthetic import ClassificationTask

    if quick:  # exercised by tests; --full runs the real thing
        n_workers, n_events, cap = 10, 20_000, 48
        hidden, n_features = (64,), 32
        max_batch = 8
    else:
        n_workers, n_events, cap = 100, 1_000_000, 96
        hidden, n_features = (2048, 2304, 2048), 512
        max_batch = 16

    params0 = mlp_init(jax.random.PRNGKey(0), n_features, 10, hidden=hidden)
    total = ParamSpace.from_tree(params0).total
    if not quick:
        assert total >= 10_000_000, total

    t0 = time.perf_counter()
    sched = async_sim.make_schedule(n_workers, n_events, seed=7, hetero=0.8)
    dt_sched = time.perf_counter() - t0
    t0 = time.perf_counter()
    batches = async_sim.batch_schedule(sched, max_batch=max_batch)
    dt_batch = time.perf_counter() - t0
    mean_b = n_events / len(batches)

    task = ClassificationTask(n_features=n_features, n_classes=10,
                              batch_size=8, seed=0, noise=1.0)

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            lp = jax.nn.log_softmax(mlp_apply(p, x))
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        return task.batch(e, worker=k)

    strat = make_strategy("dgs", density=0.001, momentum=0.7,
                          quantize="int8")
    tr = async_sim.AsyncTrainer(strat, grad_fn, n_workers, lr=0.05,
                                secondary_density=0.001)
    cap_sched = sched[:cap]
    pool = [batch_fn(e, int(cap_sched[e])) for e in range(cap)]
    pooled_fn = lambda e, k: pool[e]  # noqa: E731
    tr.run(params0, cap_sched, pooled_fn)                             # warm
    tr.run_batched(params0, cap_sched, pooled_fn, max_batch=max_batch)
    t0 = time.perf_counter()
    _, _, h_s = tr.run(params0, cap_sched, pooled_fn)
    dt_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, _, h_b = tr.run_batched(params0, cap_sched, pooled_fn,
                               max_batch=max_batch)
    dt_batched = time.perf_counter() - t0
    assert np.array_equal(h_s.losses, h_b.losses)
    assert (h_s.up_bytes, h_s.down_bytes) == (h_b.up_bytes, h_b.down_bytes)

    config = {"model_params": int(total), "n_workers": n_workers,
              "n_events": n_events, "timed_events": cap,
              "strategy": "dgs", "density": 0.001, "quantize": "int8",
              "secondary_density": 0.001, "max_batch": max_batch}
    record_perf("scalability", "big/schedule", config=config,
                events_per_sec=n_events / dt_sched, nbytes=0,
                wall_clock_s=dt_sched)
    record_perf("scalability", "big/batching", config=config,
                events_per_sec=n_events / dt_batch, nbytes=0,
                wall_clock_s=dt_batch)
    record_perf("scalability", "big/serial_loop", config=config,
                events_per_sec=cap / dt_serial,
                nbytes=h_s.up_bytes + h_s.down_bytes,
                wall_clock_s=dt_serial)
    record_perf("scalability", "big/batched_loop", config=config,
                events_per_sec=cap / dt_batched,
                nbytes=h_b.up_bytes + h_b.down_bytes,
                wall_clock_s=dt_batched)
    return [
        csv_row("big/schedule_1M", dt_sched / n_events * 1e6,
                f"workers={n_workers};events={n_events}"),
        csv_row("big/batch_schedule", dt_batch / n_events * 1e6,
                f"batches={len(batches)};mean_size={mean_b:.1f}"),
        csv_row("big/serial_loop", dt_serial / cap * 1e6,
                f"params={total};timed_events={cap}"),
        csv_row("big/batched_loop", dt_batched / cap * 1e6,
                f"speedup={dt_serial / dt_batched:.2f}x"),
    ]


def smoke() -> int:
    """CI entry: exercise the fused arena + scan + batched hot paths.

    Asserts (a) the arena event loop beats the per-leaf baseline,
    (b) the batched event loop beats the serial reference by >= 1.2x,
    and (c) the 2-shard commit throughput is >= the single server's.
    Wall-clock on shared CI runners is noisy, so a below-threshold first
    measurement gets ONE re-run; the bit/byte-parity asserts inside
    run_scan/run_batched_loop/run_sharded stay exact.  Writes
    ``BENCH_scalability.json``.
    """
    from .common import write_bench_artifacts

    rows, speedup = run_arena(quick=True)
    if speedup <= 1.0:   # timing flake? measure once more
        rows2, speedup = run_arena(quick=True)
        rows += rows2
    rows += run_scan(quick=True)
    brows, bspeed = run_batched_loop(quick=True)
    if bspeed < 1.2:     # timing flake? measure once more
        brows2, bspeed = run_batched_loop(quick=True)
        brows += brows2
    rows += brows
    srows, thru = run_sharded(quick=True)
    if thru[2] < thru[1]:  # timing flake? measure once more
        srows2, thru = run_sharded(quick=True)
        srows += srows2
    rows += srows
    print("\n".join(rows))
    for path in write_bench_artifacts():
        print(f"wrote {path}")
    ok = True
    if speedup < 0.8:
        print(f"FAIL: fused arena slower than per-leaf ({speedup:.2f}x)")
        ok = False
    if bspeed < 1.2:
        print(f"FAIL: batched loop below 1.2x vs serial ({bspeed:.2f}x)")
        ok = False
    if thru[2] < thru[1]:
        print(f"FAIL: 2-shard commit throughput below single-server "
              f"({thru[2]:.1f} vs {thru[1]:.1f} events/s)")
        ok = False
    if ok:
        print(f"{'OK' if speedup > 1.0 else 'WARN (noisy run)'}: "
              f"fused arena event loop {speedup:.2f}x vs per-leaf; "
              f"batched loop {bspeed:.2f}x vs serial; "
              f"2-shard commit {thru[2] / thru[1]:.2f}x vs single")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    if "--smoke-mesh" in sys.argv:
        raise SystemExit(smoke_mesh())
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    out = run(quick=True)
    arena_rows, _ = run_arena(quick=True)
    out += arena_rows + run_scan(quick=True)
    batched_rows, _ = run_batched_loop(quick=True)
    out += batched_rows
    sharded_rows, _ = run_sharded(quick=True)
    out += sharded_rows
    mesh_rows, _ = run_mesh_sharded(quick=True)
    out += mesh_rows
    print("\n".join(out))
