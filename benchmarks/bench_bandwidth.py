"""Paper Fig. 4: wall-clock under constrained bandwidth.

We measure the REAL per-iteration wire bytes of each strategy on the async
cluster (same accounting as the paper: upward message + downward model/diff)
and model iteration time as

    t_iter = t_compute + bytes / bandwidth

with the paper's two settings (10 Gbps default, 1 Gbps constrained).  The
paper reports 88 min (DGS) vs 506 min (ASGD) at 1 Gbps = 5.7x; the model
below reproduces the same mechanism (dense down+up vs dual-way sparse) on a
parameterizable model size."""
from __future__ import annotations

import numpy as np

from .common import (csv_row, make_classification_problem, record_perf,
                     run_strategy)

GBPS = 1e9 / 8  # bytes per second per Gbps


def run(quick: bool = False):
    n_events = 150 if quick else 600
    rows = []
    params0, grad_fn, batch_fn, _ = make_classification_problem(seed=0)
    n_params = sum(int(np.prod(np.asarray(v).shape))
                   for v in params0.values())
    measured = {}
    for name, secondary in [("asgd", None), ("dgs", None),
                            ("dgs", 0.01)]:
        tag = name + ("+2nd" if secondary else "")
        final, hist, dt = run_strategy(
            name, params0, grad_fn, batch_fn, n_workers=8,
            n_events=n_events, lr=0.08, density=0.01, momentum=0.7,
            secondary_density=secondary, seed=4)
        per_iter = (hist.up_bytes + hist.down_bytes) / n_events
        measured[tag] = per_iter
        record_perf("bandwidth", f"bytes/{tag}",
                    config={"strategy": name, "density": 0.01,
                            "secondary_density": secondary,
                            "n_workers": 8, "n_events": n_events},
                    events_per_sec=n_events / dt,
                    nbytes=hist.up_bytes + hist.down_bytes,
                    wall_clock_s=dt)
        rows.append(csv_row(f"fig4/bytes/{tag}", dt / n_events * 1e6,
                            f"bytes_per_iter={per_iter:.0f}"))
    # measured-wire rows: per-iteration serialized frame bytes of the
    # cluster codec (headers, scales, bit-packed values) per quantize mode
    # — what a real TCP run of launch/cluster.py moves per event
    for mode in ("bf16", "int8", "tern"):
        _, hist, dt = run_strategy(
            "dgs", params0, grad_fn, batch_fn, n_workers=8,
            n_events=n_events, lr=0.08, density=0.01, momentum=0.7,
            secondary_density=0.01, seed=4, quantize=mode)
        record_perf("bandwidth", f"wire/dgs+2nd/{mode}",
                    config={"strategy": "dgs", "density": 0.01,
                            "secondary_density": 0.01, "quantize": mode,
                            "n_workers": 8, "n_events": n_events},
                    events_per_sec=n_events / dt,
                    nbytes=hist.up_bytes + hist.down_bytes,
                    wall_clock_s=dt)
        rows.append(csv_row(
            f"fig4/wire/dgs+2nd/{mode}", 0.0,
            f"up_per_iter={hist.up_bytes / n_events:.0f};"
            f"down_per_iter={hist.down_bytes / n_events:.0f}"))

    # analytic scale-up: ResNet-18-sized model (11.7M params), fp32
    scale = 11.7e6 / n_params
    t_compute = 0.118  # s/iter on K80 (paper: 50 epochs/88min incl. comm)
    for bw_gbps in (10.0, 1.0):
        times = {}
        for tag, per_iter in measured.items():
            wire = per_iter * scale
            times[tag] = t_compute + wire / (bw_gbps * GBPS)
        speedup = times["asgd"] / times["dgs+2nd"]
        rows.append(csv_row(
            f"fig4/model_{bw_gbps:g}gbps", 0.0,
            f"asgd_s={times['asgd']:.3f};dgs_s={times['dgs']:.3f};"
            f"dgs2nd_s={times['dgs+2nd']:.3f};speedup={speedup:.1f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
