"""Paper Table II: LSTM on the sequence task — symbol error rate (the WER
stand-in) for single-node SGD vs DGC-async vs DGS at 4 workers."""
from __future__ import annotations

from .common import csv_row, make_copy_problem, run_strategy


def run(quick: bool = False):
    n_events = 250 if quick else 1500
    params0, grad_fn, batch_fn, error_rate = make_copy_problem(
        seed=0, copy_len=4, delay=4, hidden=96)
    rows = []
    for name in ["msgd", "dgc_async", "dgs"]:
        final, hist, dt = run_strategy(
            name, params0, grad_fn, batch_fn, n_workers=1 if name == "msgd"
            else 4, n_events=n_events, lr=0.3, density=0.05, momentum=0.7,
            seed=3)
        err = error_rate(final)
        rows.append(csv_row(f"table2/{name}", dt / n_events * 1e6,
                            f"err={err:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
