"""Data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import (ClassificationTask, SequenceCopyTask,
                                  TokenStream)
from repro.optim import (adamw_init, adamw_update, cosine_lr, momentum_init,
                         momentum_update, sgd_update, step_decay_lr)


class TestData:
    def test_tokenstream_deterministic_and_structured(self):
        ts = TokenStream(vocab_size=64, seq_len=32, batch_size=4, seed=1)
        a = ts.batch(0)["tokens"]
        b = ts.batch(0)["tokens"]
        np.testing.assert_array_equal(a, b)
        c = ts.batch(1)["tokens"]
        assert not np.array_equal(a, c)
        assert a.shape == (4, 32) and a.dtype == jnp.int32
        assert int(a.max()) < 64
        # markov structure: bigram entropy < unigram entropy over vocab
        toks = np.asarray(ts.batch(2)["tokens"]).reshape(-1)
        assert len(np.unique(toks)) <= 64

    def test_classification_separable(self):
        task = ClassificationTask(n_features=16, n_classes=4, batch_size=64,
                                  noise=0.1)
        x, y = task.batch(0)
        centers = np.asarray(task.centers())
        pred = np.argmin(
            ((np.asarray(x)[:, None] - centers[None]) ** 2).sum(-1), axis=1)
        assert (pred == np.asarray(y)).mean() > 0.95

    def test_copy_task_shapes(self):
        t = SequenceCopyTask(copy_len=4, delay=3, batch_size=2)
        x, y = t.batch(0)
        assert x.shape == y.shape == (2, t.seq_len)
        np.testing.assert_array_equal(np.asarray(y[:, -4:]),
                                      np.asarray(x[:, 1:5]))


class TestOptim:
    def _setup(self):
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.full((4,), 2.0)}
        return params, grads

    def test_sgd(self):
        p, g = self._setup()
        p2 = sgd_update(p, g, lr=0.5)
        np.testing.assert_allclose(p2["w"], 0.0)

    def test_momentum_accumulates(self):
        p, g = self._setup()
        st = momentum_init(p)
        p, st = momentum_update(p, g, st, lr=0.1, momentum=0.5)
        p, st = momentum_update(p, g, st, lr=0.1, momentum=0.5)
        np.testing.assert_allclose(st.velocity["w"], 2.0 + 0.5 * 2.0)

    def test_adamw_direction(self):
        p, g = self._setup()
        st = adamw_init(p)
        p2, st = adamw_update(p, g, st, lr=0.1)
        assert float(p2["w"][0]) < 1.0

    def test_schedules(self):
        lr = step_decay_lr(1.0, total_steps=100)
        assert lr(0) == 1.0 and abs(lr(65) - 0.1) < 1e-9
        assert abs(lr(90) - 0.01) < 1e-9
        c = cosine_lr(1.0, warmup=10, total_steps=100)
        assert c(0) < c(9) <= 1.0
        assert c(99) < 0.2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)},
                "t": (jnp.zeros((2,)), jnp.ones((1,), jnp.int32))}
        path = os.path.join(tmp_path, "ckpt")
        save_checkpoint(path, tree, step=7, extra={"note": "hi"})
        restored, meta = load_checkpoint(path, jax.tree.map(
            lambda x: jnp.zeros_like(x), tree))
        assert meta["step"] == 7 and meta["extra"]["note"] == "hi"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_structure_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "ckpt")
        save_checkpoint(path, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            load_checkpoint(path, {"b": jnp.ones((2,))})
        with pytest.raises(ValueError):
            load_checkpoint(path, {"a": jnp.ones((3,))})
