"""Compression-engine parity: every engine against the pre-refactor oracle,
the SAMomentum telescoping invariant under every engine, auto-dispatch, and
uniform wire quantization (DESIGN.md §Compression-engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import engine as E
from repro.core import server as ps
from repro.core.baselines import make_strategy
from repro.core.distributed import ExchangeConfig
from repro.core.engine import CompressionSpec
from repro.core.sparsify import SparseLeaf


def _oracle_leaf_update(u_prev, grad, *, momentum, lr, k):
    """The pre-refactor SAMomentum step (samomentum.leaf_update +
    sparsify.topk_select, verbatim) — the bit-for-bit contract for the
    exact engine."""
    u = momentum * u_prev + lr * grad
    flat = u.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    vals = flat[idx]
    mask = jnp.zeros(flat.shape, dtype=bool).at[idx].set(True)
    u_new = jnp.where(mask, flat, flat / momentum).reshape(u.shape)
    return vals, idx, u_new


class TestExactParity:
    def test_exact_matches_prerefactor_oracle_bitforbit(self):
        key = jax.random.PRNGKey(0)
        for n, k in [(64, 8), (100, 1), (1000, 100), (16, 16)]:
            u = jax.random.normal(jax.random.fold_in(key, n), (n,))
            g = jax.random.normal(jax.random.fold_in(key, n + 1), (n,))
            msg, u1 = E.samomentum_step(
                u, g, momentum=0.7, lr=0.1, k=k,
                spec=CompressionSpec(engine="exact"))
            ov, oi, ou = _oracle_leaf_update(u, g, momentum=0.7, lr=0.1, k=k)
            np.testing.assert_array_equal(np.asarray(msg.values),
                                          np.asarray(ov))
            np.testing.assert_array_equal(np.asarray(msg.indices),
                                          np.asarray(oi))
            np.testing.assert_array_equal(np.asarray(u1), np.asarray(ou))

    def test_select_rows_exact_matches_topk(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
        vals, idx = E.select_rows(x, 11, CompressionSpec(engine="exact"))
        _, ri = jax.lax.top_k(jnp.abs(x), 11)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
        np.testing.assert_array_equal(
            np.asarray(vals),
            np.asarray(jnp.take_along_axis(x, ri, axis=1)))


class TestBlockwise:
    def test_blockwise_exact_when_r_ge_k(self):
        """With block_r >= k every global winner is a block winner, so the
        blockwise support equals the exact support."""
        for n, k in [(512, 16), (3000, 64), (9000, 33)]:
            x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
            exact = E.select(x, k, CompressionSpec(engine="exact"))
            block = E.select(x, k, CompressionSpec(engine="blockwise"))
            assert set(np.asarray(block.indices).tolist()) == \
                set(np.asarray(exact.indices).tolist())
            np.testing.assert_allclose(
                np.sort(np.asarray(block.values)),
                np.sort(np.asarray(exact.values)), atol=0)

    def test_blockwise_samomentum_matches_exact_when_r_ge_k(self):
        u = jax.random.normal(jax.random.PRNGKey(2), (2000,))
        g = jax.random.normal(jax.random.PRNGKey(3), (2000,))
        msg_b, u_b = E.samomentum_step(
            u, g, momentum=0.6, lr=0.05, k=50,
            spec=CompressionSpec(engine="blockwise"))
        msg_e, u_e = E.samomentum_step(
            u, g, momentum=0.6, lr=0.05, k=50,
            spec=CompressionSpec(engine="exact"))
        assert set(np.asarray(msg_b.indices).tolist()) == \
            set(np.asarray(msg_e.indices).tolist())
        np.testing.assert_allclose(np.asarray(u_b), np.asarray(u_e),
                                   atol=1e-6)

    def test_blockwise_select_rows(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (3, 1500))
        bv, bi = E.select_rows(x, 9, CompressionSpec(engine="blockwise"))
        ev, ei = E.select_rows(x, 9, CompressionSpec(engine="exact"))
        for r in range(3):
            assert set(np.asarray(bi[r]).tolist()) == \
                set(np.asarray(ei[r]).tolist())


@settings(max_examples=10, deadline=None)
@given(st.integers(32, 2048), st.floats(0.3, 0.95), st.integers(0, 2 ** 31))
def test_property_telescoping_invariant_every_engine(n, m, seed):
    """Alg. 3 line 11 under EVERY engine (including the approximate
    blockwise mode): sent coords keep the accumulated velocity, unsent are
    exactly divided by m — so no mass ever leaks out of the velocity.

    This is the invariant that makes Eq. (13) telescope; for blockwise with
    block_r < k it is only true because of the scatter_apply support repair
    (thresholded-but-unshipped coordinates must be rescaled too).
    """
    key = jax.random.PRNGKey(seed)
    u0 = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    k = max(1, n // 8)
    specs = [
        CompressionSpec(engine="exact"),
        CompressionSpec(engine="sampled", sample_size=64),
        CompressionSpec(engine="blockwise"),
        CompressionSpec(engine="blockwise", block_r=1),  # approximate mode
    ]
    uacc = np.asarray(m * u0 + 0.1 * g, np.float64)
    for spec in specs:
        msg, u1 = E.samomentum_step(u0, g, momentum=m, lr=0.1, k=k,
                                    spec=spec)
        sent = np.zeros(n, bool)
        sent[np.asarray(msg.indices)] = True
        u1 = np.asarray(u1, np.float64)
        np.testing.assert_allclose(
            np.where(sent, u1, u1 * m), uacc, atol=5e-5,
            err_msg=f"engine spec {spec}")
        # and the decoded message carries exactly the accumulated velocity
        # of the sent support (sampled underflow pads with decode-neutral
        # zero-value duplicates, so compare through the scatter-add decode)
        decoded = np.zeros(n, np.float64)
        np.add.at(decoded, np.asarray(msg.indices),
                  np.asarray(msg.values, np.float64))
        np.testing.assert_allclose(
            decoded, np.where(sent, uacc, 0.0), atol=5e-5,
            err_msg=f"engine spec {spec}")


class TestSampledNoStarvation:
    def test_spike_ships_even_when_sample_misses_it(self):
        """Regression: a structurally sparse tensor (e.g. one embedding row
        touched) whose nonzeros the strided subsample misses entirely must
        still ship its mass — exact zeros never pass the thr=0 estimate,
        and candidates are top-k'd by magnitude, never index order."""
        x = jnp.zeros((64,)).at[17].set(5.0)
        leaf = E.select(x, 4, CompressionSpec(engine="sampled",
                                              sample_size=8))
        idx = np.asarray(leaf.indices)
        vals = np.asarray(leaf.values)
        assert 17 in idx.tolist()
        np.testing.assert_allclose(vals[idx == 17][0], 5.0)
        # padding slots are decode-neutral
        np.testing.assert_allclose(vals[idx != 17], 0.0)

    def test_repeated_steps_transmit_the_spike(self):
        """Iterating SAMomentum with engine='sampled' on a gradient the
        subsample never sees must not silently starve the coordinate."""
        spec = CompressionSpec(engine="sampled", sample_size=8)
        u = jnp.zeros((64,))
        shipped = 0.0
        for _ in range(5):
            g = jnp.zeros((64,)).at[17].set(1.0)
            msg, u = E.samomentum_step(u, g, momentum=0.5, lr=1.0, k=4,
                                       spec=spec)
            idx = np.asarray(msg.indices)
            shipped += float(np.asarray(msg.values)[idx == 17].sum())
        assert shipped > 4.0  # ~ lr * sum(g) across steps

    def test_underflow_padding_is_decode_neutral(self):
        """The zero-valued duplicate padding must decode to exactly the
        shipped tensor through BOTH decode paths (accumulating
        sparse_to_dense and the server's .add receive)."""
        from repro.core.sparsify import sparse_to_dense

        x = jnp.zeros((64,)).at[17].set(5.0)
        leaf = E.select(x, 4, CompressionSpec(engine="sampled",
                                              sample_size=8))
        np.testing.assert_allclose(np.asarray(sparse_to_dense(leaf)),
                                   np.asarray(x))

    def test_exact_when_passers_fit_candidate_cap(self):
        """The compaction is exact whenever <= 4k coordinates pass the
        sampled threshold (the common case: the estimator targets ~k)."""
        x = jax.random.normal(jax.random.PRNGKey(11), (4096,))
        sampled = E.select(x, 64, CompressionSpec(engine="sampled"))
        exact = E.select(x, 64, CompressionSpec(engine="exact"))
        # full-tensor sample -> exact threshold -> identical support
        assert set(np.asarray(sampled.indices).tolist()) == \
            set(np.asarray(exact.indices).tolist())


class TestAutoDispatch:
    def test_auto_respects_sampled_threshold_above(self):
        spec = CompressionSpec(engine="auto", sampled_threshold_above=1000)
        assert E.resolve_engine(spec, 999).name == "exact"
        assert E.resolve_engine(spec, 1000).name == "sampled"
        assert E.resolve_engine(spec, 1 << 30).name == "sampled"

    def test_pinned_engine_ignores_threshold(self):
        spec = CompressionSpec(engine="exact", sampled_threshold_above=1)
        assert E.resolve_engine(spec, 1 << 30).name == "exact"

    def test_exchange_config_threads_the_knob(self):
        """The once-dead ExchangeConfig.sampled_threshold_above now drives
        the auto dispatch of every mesh selection."""
        cfg = ExchangeConfig(engine="auto", sampled_threshold_above=128)
        spec = cfg.spec()
        assert spec.sampled_threshold_above == 128
        assert E.resolve_engine(spec, 127).name == "exact"
        assert E.resolve_engine(spec, 128).name == "sampled"

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError, match="unknown engine"):
            E.get_engine("nope")
        with pytest.raises(ValueError, match="unknown engine"):
            E.select(jnp.ones((8,)), 2, CompressionSpec(engine="nope"))


class TestPluggability:
    def test_registered_custom_engine_is_usable_everywhere(self):
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class FirstK:
            """Degenerate selector: always ships coordinates 0..k-1."""
            name = "first_k"

            @classmethod
            def from_spec(cls, spec):
                return cls()

            def select(self, x, k):
                idx = jnp.arange(k, dtype=jnp.int32)
                return SparseLeaf(values=x[:k], indices=idx,
                                  size=x.shape[0])

            def select_rows(self, x2d, k):
                idx = jnp.broadcast_to(
                    jnp.arange(k, dtype=jnp.int32), (x2d.shape[0], k))
                return x2d[:, :k], idx

        E.register_engine(FirstK)
        try:
            spec = CompressionSpec(engine="first_k")
            msg, u1 = E.samomentum_step(
                jnp.zeros((10,)), jnp.arange(10.0), momentum=0.5, lr=1.0,
                k=3, spec=spec)
            np.testing.assert_array_equal(np.asarray(msg.indices), [0, 1, 2])
            # unsent coords rescaled by 1/m, sent kept
            np.testing.assert_allclose(np.asarray(u1)[3:],
                                       np.arange(3.0, 10.0) / 0.5)
        finally:
            del E.ENGINES["first_k"]


class TestUniformQuantization:
    def test_engine_level_tern_quantization(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (256,))
        leaf = E.select(x, 16, CompressionSpec(engine="exact",
                                               quantize="tern"))
        mags = np.unique(np.abs(np.asarray(leaf.values)))
        assert mags.size == 1  # sign * shared scale

    def test_non_dgs_strategies_quantize_too(self):
        """Quantization used to be DGS-only; it now composes with every
        sparse strategy through the engine layer."""
        params = {"w": jnp.zeros((32,))}
        grads = {"w": jax.random.normal(jax.random.PRNGKey(6), (32,))}
        for name in ("gd_async", "dgc_async", "dgs_plain"):
            s = make_strategy(name, density=0.25, quantize="int8")
            assert s.value_bits == 8
            st_, msg = s.step(s.init(params), grads, lr=0.1)
            assert isinstance(msg, SparseLeaf) and msg.k == 8

    def test_tern_scale_ignores_padding_zeros(self):
        """The shared tern magnitude is computed over nonzero entries only:
        the sampled engine's zero-valued padding must not dilute it."""
        x = jnp.zeros((64,)).at[17].set(5.0)
        leaf = E.select(x, 4, CompressionSpec(engine="sampled",
                                              sample_size=8,
                                              quantize="tern"))
        vals = np.asarray(leaf.values)
        nz = vals != 0.0
        np.testing.assert_allclose(vals[nz], 5.0)   # undiluted magnitude
        assert nz.sum() == 1

    def test_quantization_not_fed_back_into_velocity(self):
        """TernGrad-style unbiased wire: u_new must be computed from the
        UNquantized velocity, message values from the quantized one."""
        u = jax.random.normal(jax.random.PRNGKey(7), (64,))
        g = jax.random.normal(jax.random.PRNGKey(8), (64,))
        msg_q, u_q = E.samomentum_step(
            u, g, momentum=0.7, lr=0.1, k=8,
            spec=CompressionSpec(engine="exact", quantize="tern"))
        msg_f, u_f = E.samomentum_step(
            u, g, momentum=0.7, lr=0.1, k=8,
            spec=CompressionSpec(engine="exact"))
        np.testing.assert_array_equal(np.asarray(u_q), np.asarray(u_f))
        assert not np.array_equal(np.asarray(msg_q.values),
                                  np.asarray(msg_f.values))


class TestServerSecondaryCompression:
    def test_send_through_sampled_engine_is_thresholded(self):
        """Secondary compression through the sampled engine ships exactly k
        slots whose (nonzero) values all pass the sampled threshold, and
        the difference-tracking remainder conserves the unshipped mass."""
        from repro.core.sparsify import sampled_threshold

        params0 = {"w": jnp.zeros((64,))}
        state = ps.init(params0, n_workers=1)
        rng = np.random.default_rng(3)
        msg = SparseLeaf(jnp.asarray(rng.normal(size=8), jnp.float32),
                         jnp.asarray(rng.choice(64, 8, replace=False),
                                     jnp.int32), 64)
        state = ps.receive(state, msg)
        diff = np.asarray(state.M - state.v[0])
        _, G = ps.send(state, 0, secondary_density=0.1,
                       spec=CompressionSpec(engine="sampled",
                                            sample_size=16))
        leaf = G
        assert leaf.k == 6  # density_to_k(64, 0.1)
        thr = float(sampled_threshold(jnp.asarray(diff), 0.1,
                                      sample_size=16))
        vals = np.asarray(leaf.values)
        assert np.all((vals == 0.0) | (np.abs(vals) >= thr))
        # shipped values are the true diff values at their indices
        nz = vals != 0.0
        np.testing.assert_allclose(vals[nz],
                                   diff[np.asarray(leaf.indices)[nz]],
                                   atol=1e-6)


class TestStrategiesAcrossEngines:
    @pytest.mark.parametrize("engine", ["exact", "sampled", "blockwise"])
    def test_dgs_step_runs_and_ships_k(self, engine):
        from repro.core.paramspace import ParamSpace

        params = {"w": jnp.zeros((300,)), "b": jnp.zeros((40,))}
        grads = jax.tree.map(
            lambda p: jax.random.normal(jax.random.PRNGKey(9), p.shape),
            params)
        s = make_strategy("dgs", density=0.1, engine=engine)
        st_, msg = s.step(s.init(params), grads, lr=0.1)
        space = ParamSpace.from_tree(params)
        seg = s.message_seg(space)
        assert sorted(seg) == [4, 30]
        assert msg.k == 34 and msg.size == space.total
        parts = space.split(msg, seg)
        assert sorted(p.k for p in parts) == [4, 30]
