"""Optional-hypothesis shim for the property tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt); offline CI
images may not carry it.  When it is installed, this module re-exports the
real ``given``/``settings``/``strategies``.  When it is missing, a minimal
fallback runs each property test over a handful of DETERMINISTIC draws
(seeded numpy RNG, plus the strategy's boundary values) — far weaker than
hypothesis's shrinking search, but it keeps the properties exercised instead
of erroring at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback: fixed-example property runner
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # boundary pair + seeded random draws

    class _Strategy:
        def __init__(self, draw, bounds=()):
            self._draw = draw
            self.bounds = bounds  # deterministic boundary examples

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801  (mimics the hypothesis module name)
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                bounds=(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                bounds=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))],
                bounds=(elements[0], elements[-1]))

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                for case in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(1234 + case)
                    if case < 2:  # all-min, then all-max
                        ex = tuple(s.bounds[case] for s in strats)
                    else:
                        ex = tuple(s.draw(rng) for s in strats)
                    fn(*args, *ex, **kwargs)
            # NOT functools.wraps: pytest must see the zero-arg signature,
            # not the strategy parameters (it would treat them as fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**kwargs):
        return lambda fn: fn
