"""Cluster runtime: simulator bit-parity, TCP, federated scenarios."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_sim, make_strategy, server as ps
from repro.core.engine import CompressionSpec
from repro.cluster import run_inprocess
from repro.cluster.client import ClusterClient
from repro.cluster.coordinator import Coordinator
from repro.cluster.scenarios import (ClientPlan, dirichlet_class_weights,
                                     hetero_plans, participates)
from repro.cluster.transport import (TcpClientTransport,
                                     TcpCoordinatorTransport)


def _problem():
    key = jax.random.PRNGKey(0)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        kk = jax.random.PRNGKey(int(e) * 131 + int(k) + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    return grad_fn, batch_fn, params0


# ---------------------------------------------------------------------------
# the keystone contract: bit-parity with AsyncTrainer on the same schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw,sd,spec", [
    ("asgd", {}, None, CompressionSpec(engine="exact")),
    ("dgs", {"density": 0.2, "momentum": 0.7}, 0.1,
     CompressionSpec(engine="exact")),
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}, 0.1,
     CompressionSpec(engine="exact", quantize="bf16")),
    ("gd_async", {"density": 0.2, "quantize": "tern"}, None,
     CompressionSpec(engine="exact")),
])
def test_inprocess_cluster_bit_parity(name, kw, sd, spec):
    """Same schedule -> bit-identical losses/params, and the simulator's
    byte accounting == the bytes actually moved through the transport."""
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(3, 40, seed=7, hetero=0.9)
    strat = make_strategy(name, **kw)
    tr = async_sim.AsyncTrainer(strat, grad_fn, 3, lr=0.03,
                                secondary_density=sd, secondary_spec=spec)
    f_sim, _, h_sim = tr.run(params0, sched, batch_fn)
    f_cl, h_cl = run_inprocess(strat, grad_fn, params0, batch_fn,
                               schedule=sched, lr=0.03,
                               secondary_density=sd, secondary_spec=spec)
    np.testing.assert_array_equal(h_sim.losses, h_cl.losses)
    np.testing.assert_array_equal(h_sim.worker_ids, h_cl.worker_ids)
    np.testing.assert_array_equal(h_sim.staleness, h_cl.staleness)
    for a, b in zip(jax.tree.leaves(f_sim), jax.tree.leaves(f_cl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_sim.up_bytes == h_cl.up_bytes
    assert h_sim.down_bytes == h_cl.down_bytes


# ---------------------------------------------------------------------------
# TCP backend
# ---------------------------------------------------------------------------

def test_tcp_two_clients_converge():
    grad_fn, batch_fn, params0 = _problem()
    strat = make_strategy("dgs", density=0.2, momentum=0.7, quantize="int8")
    ct = TcpCoordinatorTransport()
    coord = Coordinator(transport=ct, params0=params0, n_slots=2,
                        secondary_density=0.2, recv_timeout=120.0)

    def client_main(cid):
        t = TcpClientTransport("127.0.0.1", ct.port, cid)
        ClusterClient(
            transport=t, strategy=strat, grad_fn=grad_fn, params0=params0,
            batch_fn=batch_fn, plan=ClientPlan(client_id=cid, n_rounds=8),
            lr=0.05).run()
        t.close()

    threads = [threading.Thread(target=client_main, args=(i,), daemon=True)
               for i in range(2)]
    for t in threads:
        t.start()
    final, hist = coord.serve()
    for t in threads:
        t.join(timeout=60)
    ct.close()
    assert len(hist.losses) == 16
    assert hist.losses[-4:].mean() < hist.losses[:4].mean()
    assert hist.up_bytes > 0 and hist.down_bytes > 0


# ---------------------------------------------------------------------------
# federated scenarios
# ---------------------------------------------------------------------------

def test_scenario_elastic_partial_faulty_is_deterministic():
    """Joins/leaves + 70% participation + drops: runs, converges, and the
    whole virtual-time execution replays bit-identically."""
    grad_fn, batch_fn, params0 = _problem()
    plans = hetero_plans(4, 10, hetero=0.8, seed=3, participation=0.7,
                         late_join=1, early_leave=1, bandwidth=1e5,
                         drop_prob=0.15)
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    runs = [run_inprocess(strat, grad_fn, params0, batch_fn, plans=plans,
                          lr=0.05, inject_faults=True,
                          secondary_density=0.25) for _ in range(2)]
    (f1, h1), (f2, h2) = runs
    n_max = 3 * 10 + 5  # 3 full-life clients + early leaver's half life
    assert 5 < len(h1.losses) < n_max
    assert h1.losses[-3:].mean() < h1.losses[:3].mean()
    np.testing.assert_array_equal(h1.losses, h2.losses)
    np.testing.assert_array_equal(h1.worker_ids, h2.worker_ids)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_grows_and_reuses_slots():
    """More clients than initial slots: v grows via ps.add_worker; a freed
    slot is zeroed and reused by the next joiner."""
    grad_fn, batch_fn, params0 = _problem()
    plans = [ClientPlan(client_id=0, n_rounds=4),
             ClientPlan(client_id=1, n_rounds=2),
             # joins after client 1 leaves: reuses its slot
             ClientPlan(client_id=2, n_rounds=4, join_time=10.0)]
    strat = make_strategy("dgs", density=0.5, momentum=0.5)
    final, hist = run_inprocess(strat, grad_fn, params0, batch_fn,
                                plans=plans, n_workers=1, lr=0.05)
    assert len(hist.losses) == 10
    # slot ids stay within the grown pool (1 initial + 1 grown)
    assert set(hist.worker_ids.tolist()) <= {0, 1}


def test_participation_draws_are_seeded():
    plan = ClientPlan(client_id=1, n_rounds=100, participation=0.5, seed=9)
    a = [participates(plan, r) for r in range(100)]
    b = [participates(plan, r) for r in range(100)]
    assert a == b
    assert 20 < sum(a) < 80


def test_dirichlet_shards_skew_with_alpha():
    w_skew = dirichlet_class_weights(16, 10, 0.1, seed=0)
    w_iid = dirichlet_class_weights(16, 10, 1000.0, seed=0)
    np.testing.assert_allclose(w_skew.sum(1), 1.0, atol=1e-9)
    assert w_skew.max(1).mean() > 0.6     # concentrated
    assert w_iid.max(1).mean() < 0.2      # near uniform


def test_reset_worker_zeroes_v_row():
    params0 = {"w": jnp.ones((4,))}
    state = ps.init(params0, 2)
    state, _ = ps.add_worker(state)
    assert state.v.shape[0] == 3
    msg = jnp.ones((4,), jnp.float32)   # dense arena update
    state = ps.receive(state, msg)
    state, _ = ps.send(state, 2)
    assert float(jnp.abs(state.v[2]).sum()) > 0
    state = ps.reset_worker(state, 2)
    assert float(jnp.abs(state.v[2]).sum()) == 0.0


# ---------------------------------------------------------------------------
# flight-recorder accounting: injected faults must show up in telemetry
# ---------------------------------------------------------------------------

def test_fault_policy_accounting_matches_seeded_expectations():
    """Every injected drop, observed retry, and virtual-time cost must be
    visible in the coordinator's telemetry counters — and the drop counts
    must equal a host-side replay of each FaultInjector's seeded rng."""
    grad_fn, batch_fn, params0 = _problem()
    n_rounds, drop_prob, bandwidth, delay = 8, 0.3, 1e5, 0.01
    plans = [ClientPlan(client_id=c, n_rounds=n_rounds,
                        compute_time=1.0 + 0.3 * c, bandwidth=bandwidth,
                        delay=delay, drop_prob=drop_prob, seed=11)
             for c in range(3)]
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    _, hist = run_inprocess(strat, grad_fn, params0, batch_fn, plans=plans,
                            lr=0.05, secondary_density=0.25,
                            inject_faults=True)

    counters = hist.metrics["counters"]
    clients = hist.metrics["clients"]
    assert len(hist.losses) == 3 * n_rounds   # every drop was recovered
    total_drops = 0
    for p in plans:
        cid = p.client_id
        acct = clients[cid]
        # the injector draws its rng ONCE per droppable (UP) send: the
        # n_rounds scheduled sends plus one resend per observed retry.
        # Replaying those draws must reproduce the injected drop count.
        rng = np.random.default_rng(p.fault_policy(realtime=False).seed)
        draws = rng.random(n_rounds + acct["retries"])
        assert acct["drops"] == int((draws < drop_prob).sum())
        # every drop forces a reply timeout, so retries >= drops; spurious
        # timeouts (slow first-compile) may add benign extra retransmits
        assert acct["retries"] >= acct["drops"]
        total_drops += acct["drops"]
        # per-client coordinator counters: all rounds served exactly once
        assert counters[f"client/{cid}/events"] == n_rounds
        up = counters[f"client/{cid}/up_bytes"]
        down = counters[f"client/{cid}/down_bytes"]
        assert up > 0 and down > 0
        # virtual time booked with the scheduler == the policy's formula
        # over exactly the frames the coordinator served
        expect_cost = n_rounds * delay + (up + down) / bandwidth
        np.testing.assert_allclose(counters[f"client/{cid}/virtual_cost"],
                                   expect_cost, rtol=1e-9)
    assert total_drops > 0, "policy injected nothing — test is vacuous"
    # duplicate UPs (retransmits that survived) were answered from the
    # reply cache, never re-applied
    assert counters.get("dup", 0) == counters.get("reply_cache_hits", 0)


# ---------------------------------------------------------------------------
# sharded parameter server (DESIGN.md §12)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("name,kw,sd,spec", [
    ("asgd", {}, None, CompressionSpec(engine="exact")),
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}, 0.1,
     CompressionSpec(engine="exact", quantize="bf16")),
    ("dgc_async", {"density": 0.2, "momentum": 0.7}, None,
     CompressionSpec(engine="exact")),
])
def test_sharded_inprocess_bit_parity(n_shards, name, kw, sd, spec):
    """S coordinator shards over disjoint arena ranges reproduce the
    single-server run bit-for-bit (losses, event order, final params),
    and the sharded wire bytes match the static per-shard accounting."""
    from repro.cluster import wire
    from repro.core.paramspace import ParamSpace, ShardSpec

    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(3, 24, seed=7, hetero=0.9)
    strat = make_strategy(name, **kw)
    f1, h1 = run_inprocess(strat, grad_fn, params0, batch_fn,
                           schedule=sched, lr=0.03,
                           secondary_density=sd, secondary_spec=spec)
    fS, hS = run_inprocess(strat, grad_fn, params0, batch_fn,
                           schedule=sched, lr=0.03,
                           secondary_density=sd, secondary_spec=spec,
                           n_shards=n_shards)
    np.testing.assert_array_equal(h1.losses, hS.losses)
    np.testing.assert_array_equal(h1.worker_ids, hS.worker_ids)
    np.testing.assert_array_equal(h1.staleness, hS.staleness)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(fS)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sparse upward frames have a static size: the sharded run's measured
    # up bytes must equal the per-shard static accounting exactly
    space = ParamSpace.from_tree(params0)
    up_seg = strat.message_seg(space)
    if up_seg is not None:
        sspec = ShardSpec.for_space(space, n_shards)
        per_event = sum(wire.shard_frame_bytes_static(sspec, up_seg,
                                                      strat.quantize))
        assert hS.up_bytes == per_event * len(hS.losses)
        assert h1.up_bytes == (wire.frame_bytes_static(up_seg, space.total,
                                                       strat.quantize)
                               * len(h1.losses))
    # every shard served every event; the balance counters say so
    counters = hS.metrics["counters"]
    for s in range(n_shards):
        assert counters[f"shard/{s}/events"] == len(hS.losses)
        assert counters[f"shard/{s}/arena_elems"] == \
            ShardSpec.for_space(space, n_shards).sizes[s]


def _run_tcp_lockstep(n_shards, *, rounds=6, clients=3, sd=0.2):
    """One TCP cluster run serving a lockstep round-robin schedule."""
    from repro.cluster.transport import ScheduleDriven
    from repro.core.paramspace import ParamSpace, ShardSpec

    grad_fn, batch_fn, params0 = _problem()
    strat = make_strategy("dgs", density=0.2, momentum=0.7, quantize="int8")
    order = np.tile(np.arange(clients), rounds)
    shard_spec = (ShardSpec.for_space(ParamSpace.from_tree(params0),
                                      n_shards)
                  if n_shards > 1 else None)
    cts = [TcpCoordinatorTransport() for _ in range(n_shards)]
    coords = [Coordinator(transport=cts[s], params0=params0,
                          n_slots=clients, secondary_density=sd,
                          recv_timeout=120.0,
                          scheduler=ScheduleDriven(order),
                          shard_spec=shard_spec, shard_id=s)
              for s in range(n_shards)]

    def client_main(cid):
        ts = [TcpClientTransport("127.0.0.1", ct.port, cid) for ct in cts]
        ClusterClient(
            transport=ts if n_shards > 1 else ts[0],
            shard_spec=shard_spec, pin_slot=True, strategy=strat,
            grad_fn=grad_fn, params0=params0, batch_fn=batch_fn,
            plan=ClientPlan(client_id=cid, n_rounds=rounds), lr=0.05).run()
        for t in ts:
            t.close()

    client_threads = [threading.Thread(target=client_main, args=(i,),
                                       daemon=True) for i in range(clients)]
    for t in client_threads:
        t.start()
    results = [None] * n_shards
    coord_threads = [threading.Thread(
        target=lambda s=s: results.__setitem__(s, coords[s].serve()),
        daemon=True) for s in range(1, n_shards)]
    for t in coord_threads:
        t.start()
    results[0] = coords[0].serve()
    for t in client_threads + coord_threads:
        t.join(timeout=60)
    for ct in cts:
        ct.close()
    finals = [r[0] for r in results]
    if n_shards > 1:
        leaves = [leaf for f in finals for leaf in jax.tree.leaves(f)]
        final = jax.tree.unflatten(jax.tree.structure(params0), leaves)
    else:
        final = finals[0]
    return final, [r[1] for r in results]


def test_sharded_tcp_bit_parity():
    """A 2-shard TCP cluster reproduces the 1-shard TCP run bit-for-bit
    under the same lockstep schedule — real sockets, split frames."""
    f1, (h1,) = _run_tcp_lockstep(1)
    f2, hs = _run_tcp_lockstep(2)
    for h in hs:   # every shard logged the identical event stream
        np.testing.assert_array_equal(h1.losses, h.losses)
        np.testing.assert_array_equal(h1.worker_ids, h.worker_ids)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # each shard moved fewer bytes than the whole model's single frame
    assert all(0 < h.up_bytes < h1.up_bytes for h in hs)


# ---------------------------------------------------------------------------
# device-mesh shard servers (DESIGN.md §14)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shards", [2, 4])
@pytest.mark.parametrize("name,kw,sd,spec", [
    ("asgd", {}, None, CompressionSpec(engine="exact")),
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}, 0.1,
     CompressionSpec(engine="exact", quantize="bf16")),
    ("dgs", {"density": 0.2, "momentum": 0.7, "engine": "sampled",
             "quantize": "bf16"}, None, CompressionSpec(engine="exact")),
    ("dgs", {"density": 0.2, "momentum": 0.7, "engine": "blockwise",
             "quantize": "tern"}, 0.2, CompressionSpec(engine="exact")),
    ("dgc_async", {"density": 0.2, "momentum": 0.7}, None,
     CompressionSpec(engine="exact")),
])
def test_mesh_inprocess_bit_parity(mesh_shards, name, kw, sd, spec):
    """The mesh-sharded runtime (ONE coordinator, S in-graph shard servers
    over stacked arenas) reproduces both the single-server run AND the
    S-thread sharded runtime bit-for-bit — and, unlike the S-thread
    runtime, moves exactly the single-server wire bytes (one frame per
    event, split in-graph rather than on the wire)."""
    from repro.core.paramspace import ParamSpace, ShardSpec

    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(3, 24, seed=7, hetero=0.9)
    strat = make_strategy(name, **kw)
    f1, h1 = run_inprocess(strat, grad_fn, params0, batch_fn,
                           schedule=sched, lr=0.03,
                           secondary_density=sd, secondary_spec=spec)
    fM, hM = run_inprocess(strat, grad_fn, params0, batch_fn,
                           schedule=sched, lr=0.03,
                           secondary_density=sd, secondary_spec=spec,
                           mesh_shards=mesh_shards)
    fT, hT = run_inprocess(strat, grad_fn, params0, batch_fn,
                           schedule=sched, lr=0.03,
                           secondary_density=sd, secondary_spec=spec,
                           n_shards=mesh_shards)
    np.testing.assert_array_equal(h1.losses, hM.losses)
    np.testing.assert_array_equal(h1.worker_ids, hM.worker_ids)
    np.testing.assert_array_equal(h1.staleness, hM.staleness)
    np.testing.assert_array_equal(hT.losses, hM.losses)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(fM)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(fT), jax.tree.leaves(fM)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # bytes contract: the mesh runtime speaks the SINGLE-server wire
    # protocol — the index-range split happens in-graph, not on the wire
    assert (hM.up_bytes, hM.down_bytes) == (h1.up_bytes, h1.down_bytes)
    # fixed-capacity route slots never overflowed, and every shard saw
    # every event with its static arena range
    counters = hM.metrics["counters"]
    assert counters["route_overflow"] == 0
    sspec = ShardSpec.for_space(ParamSpace.from_tree(params0), mesh_shards)
    for s in range(mesh_shards):
        assert counters[f"shard/{s}/events"] == len(hM.losses)
        assert counters[f"shard/{s}/arena_elems"] == sspec.sizes[s]


def test_mesh_and_thread_sharding_are_exclusive():
    grad_fn, batch_fn, params0 = _problem()
    strat = make_strategy("dgs", density=0.2, momentum=0.7)
    with pytest.raises(ValueError, match="exactly one"):
        run_inprocess(strat, grad_fn, params0, batch_fn,
                      schedule=np.zeros(4, np.int64), lr=0.03,
                      n_shards=2, mesh_shards=2)


def test_mesh_serving_not_implemented():
    grad_fn, batch_fn, params0 = _problem()
    strat = make_strategy("dgs", density=0.2, momentum=0.7)
    with pytest.raises(NotImplementedError, match="mesh-sharded serving"):
        run_inprocess(strat, grad_fn, params0, batch_fn,
                      schedule=np.zeros(4, np.int64), lr=0.03,
                      mesh_shards=2, n_replicas=1)
