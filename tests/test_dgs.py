"""Model-difference server invariants and the DGS == ASGD equivalence
(paper Eq. 2-5)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core import async_sim, make_strategy, server as ps
from repro.core.sparsify import SparseLeaf


def _params():
    return {"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))}


def _arena_msg(rng, *, k_b=1, k_w=3, b_val=None, b_idx=None):
    """A global-index arena message over {b: (3,), w: (6,4)} — leaves order
    alphabetical, so b occupies arena [0, 3) and w [3, 27)."""
    vb = (np.full(k_b, b_val, np.float32) if b_val is not None
          else rng.normal(size=k_b).astype(np.float32))
    ib = (np.asarray(b_idx, np.int32) if b_idx is not None
          else rng.choice(3, k_b, replace=False).astype(np.int32))
    vw = rng.normal(size=k_w).astype(np.float32)
    iw = rng.choice(24, k_w, replace=False).astype(np.int32) + 3
    return SparseLeaf(values=jnp.asarray(np.concatenate([vb, vw])),
                      indices=jnp.asarray(np.concatenate([ib, iw])),
                      size=27)


def _problem(seed=0):
    key = jax.random.PRNGKey(seed)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"][None, :3].sum() - y) ** 2)

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        kk = jax.random.PRNGKey(e * 131 + k + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    return grad_fn, batch_fn


class TestServerInvariants:
    def test_theta_equals_theta0_plus_M(self):
        """Eq. 2: global model == theta_0 + M at every timestamp."""
        params0 = _params()
        state = ps.init(params0, n_workers=2)
        rng = np.random.default_rng(0)
        # arena layout (leaves alphabetical): b = [0, 3), w = [3, 27)
        manual = np.zeros(27)
        for t in range(5):
            msg = _arena_msg(rng, b_val=0.5, b_idx=[t % 3])
            state = ps.receive(state, msg)
            np.add.at(manual, np.asarray(msg.indices),
                      -np.asarray(msg.values))
        model = ps.global_model(params0, state)
        np.testing.assert_allclose(model["b"], manual[:3], rtol=1e-6)
        np.testing.assert_allclose(model["w"].reshape(-1), manual[3:],
                                   rtol=1e-6)

    def test_v_equals_M_after_send(self):
        """Eq. 4: without secondary compression, v_k == M after serving k."""
        params0 = _params()
        state = ps.init(params0, n_workers=3)
        rng = np.random.default_rng(1)
        for t in range(4):
            state = ps.receive(state, _arena_msg(rng, k_b=1, k_w=2))
            state, G = ps.send(state, worker_id=t % 3)
            wid = t % 3
            np.testing.assert_allclose(state.v[wid], state.M, rtol=1e-6)

    def test_secondary_compression_conserves_mass(self):
        """Eq. 6: with secondary compression, (M - v_k) holds exactly the
        not-yet-shipped remainder; shipping everything reconciles."""
        params0 = _params()
        state = ps.init(params0, n_workers=1)
        rng = np.random.default_rng(2)
        for t in range(6):
            state = ps.receive(state, _arena_msg(rng, k_b=1, k_w=4))
            state, G = ps.send(state, 0, secondary_density=0.1)
        # residual = M - v is whatever wasn't shipped; a dense send clears it
        state2, G_full = ps.send(state, 0, secondary_density=None)
        np.testing.assert_allclose(state2.v[0], state2.M, rtol=1e-6)


class TestEquivalence:
    def test_dgs_plain_density1_equals_asgd(self):
        """Eq. 5: DGS transport without sparsification IS ASGD — exact."""
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(3, 60, seed=2, hetero=1.0)
        tr_a = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, 3,
                                      lr=0.05)
        tr_d = async_sim.AsyncTrainer(make_strategy("dgs_plain", density=1.0),
                                      grad_fn, 3, lr=0.05)
        fa, _, ha = tr_a.run(params0, sched, batch_fn)
        fd, _, hd = tr_d.run(params0, sched, batch_fn)
        for a, d in zip(jax.tree.leaves(fa), jax.tree.leaves(fd)):
            np.testing.assert_allclose(a, d, atol=1e-5)
        np.testing.assert_allclose(ha.losses, hd.losses, atol=1e-5)

    def test_dgs_sam_density1_matches_msgd_single_worker(self):
        """One worker, no sparsification: DGS+SAMomentum == single-node
        momentum SGD stepping on the same batches."""
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = np.zeros(30, dtype=np.int32)  # single worker
        m = 0.7
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=1.0, momentum=m), grad_fn, 1,
            lr=0.05)
        fd, _, _ = tr.run(params0, sched, batch_fn)
        batches = [batch_fn(e, 0) for e in range(30)]
        fm, _ = async_sim.run_msgd(params0, grad_fn, batches, lr=0.05,
                                   momentum=m)
        for a, b in zip(jax.tree.leaves(fd), jax.tree.leaves(fm)):
            np.testing.assert_allclose(a, b, atol=1e-4)

    def test_sparse_dgs_converges(self):
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(4, 300, seed=3, hetero=0.8)
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.125, momentum=0.5), grad_fn, 4,
            lr=0.05)
        _, _, hist = tr.run(params0, sched, batch_fn)
        assert hist.losses[-20:].mean() < 0.05 * hist.losses[:5].mean()

    def test_sparse_comm_is_smaller(self):
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(4, 40, seed=4)
        dense = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, 4,
                                       lr=0.05)
        sparse = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.1, momentum=0.7), grad_fn, 4,
            lr=0.05)
        _, _, hd = dense.run(params0, sched, batch_fn)
        _, _, hs = sparse.run(params0, sched, batch_fn)
        # measured wire framing (envelope + per-leaf headers) dominates on
        # this 27-parameter toy, so the ratio is looser than the asymptotic
        # ~2*density; test_system checks the realistic-size ratio
        assert hs.up_bytes < 0.5 * hd.up_bytes
        assert hs.down_bytes < hd.down_bytes


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(5, 40), st.integers(0, 2 ** 31))
def test_property_difference_tracking_reconstructs(n_workers, n_events,
                                                   seed):
    """Whatever the schedule, theta_0 + M always equals the serially-applied
    sum of received updates (difference tracking loses nothing)."""
    grad_fn, batch_fn = _problem(seed % 97)
    params0 = _params()
    sched = async_sim.make_schedule(n_workers, n_events, seed=seed % 1000,
                                    hetero=1.0)
    tr = async_sim.AsyncTrainer(make_strategy("dgs", density=0.2),
                                grad_fn, n_workers, lr=0.02)
    final, sstate, _ = tr.run(params0, sched, batch_fn)
    # M must equal final - theta0 exactly
    model = ps.global_model(params0, sstate)
    for a, b in zip(jax.tree.leaves(model), jax.tree.leaves(final)):
        np.testing.assert_allclose(a, b, atol=1e-6)
