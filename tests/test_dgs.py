"""Model-difference server invariants and the DGS == ASGD equivalence
(paper Eq. 2-5)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core import async_sim, make_strategy, server as ps
from repro.core.sparsify import SparseLeaf


def _params():
    return {"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))}


def _problem(seed=0):
    key = jax.random.PRNGKey(seed)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"][None, :3].sum() - y) ** 2)

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        kk = jax.random.PRNGKey(e * 131 + k + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    return grad_fn, batch_fn


class TestServerInvariants:
    def test_theta_equals_theta0_plus_M(self):
        """Eq. 2: global model == theta_0 + M at every timestamp."""
        params0 = _params()
        state = ps.init(params0, n_workers=2)
        rng = np.random.default_rng(0)
        # leaves order alphabetical: b (3,), then w (24,)
        manual = [np.zeros(3), np.zeros(24)]
        for t in range(5):
            msg = [SparseLeaf(values=jnp.asarray([0.5], jnp.float32),
                              indices=jnp.asarray([t % 3], jnp.int32),
                              size=3),
                   SparseLeaf(values=jnp.asarray(rng.normal(size=3),
                                                 dtype=jnp.float32),
                              indices=jnp.asarray(
                                  rng.choice(24, 3, replace=False),
                                  dtype=jnp.int32),
                              size=24)]
            state = ps.receive(state, msg)
            for j, m in enumerate(msg):
                np.add.at(manual[j], np.asarray(m.indices),
                          -np.asarray(m.values))
        model = ps.global_model(params0, state)
        np.testing.assert_allclose(model["b"], manual[0], rtol=1e-6)
        np.testing.assert_allclose(model["w"].reshape(-1), manual[1],
                                   rtol=1e-6)

    def test_v_equals_M_after_send(self):
        """Eq. 4: without secondary compression, v_k == M after serving k."""
        params0 = _params()
        state = ps.init(params0, n_workers=3)
        rng = np.random.default_rng(1)
        for t in range(4):
            msg = [SparseLeaf(jnp.asarray(rng.normal(size=2), jnp.float32),
                              jnp.asarray(rng.choice(24, 2, replace=False),
                                          jnp.int32), 24),
                   SparseLeaf(jnp.asarray([1.0], jnp.float32),
                              jnp.asarray([0], jnp.int32), 3)]
            state = ps.receive(state, msg)
            state, G = ps.send(state, worker_id=t % 3)
            wid = t % 3
            for M_leaf, v_leaf in zip(state.M, state.v):
                np.testing.assert_allclose(v_leaf[wid], M_leaf, rtol=1e-6)

    def test_secondary_compression_conserves_mass(self):
        """Eq. 6: with secondary compression, (M - v_k) holds exactly the
        not-yet-shipped remainder; shipping everything reconciles."""
        params0 = _params()
        state = ps.init(params0, n_workers=1)
        rng = np.random.default_rng(2)
        for t in range(6):
            msg = [SparseLeaf(jnp.asarray(rng.normal(size=4), jnp.float32),
                              jnp.asarray(rng.choice(24, 4, replace=False),
                                          jnp.int32), 24),
                   SparseLeaf(jnp.asarray([0.3], jnp.float32),
                              jnp.asarray([1], jnp.int32), 3)]
            state = ps.receive(state, msg)
            state, G = ps.send(state, 0, secondary_density=0.1)
        # residual = M - v is whatever wasn't shipped; a dense send clears it
        state2, G_full = ps.send(state, 0, secondary_density=None)
        for M_leaf, v_leaf in zip(state2.M, state2.v):
            np.testing.assert_allclose(v_leaf[0], M_leaf, rtol=1e-6)


class TestEquivalence:
    def test_dgs_plain_density1_equals_asgd(self):
        """Eq. 5: DGS transport without sparsification IS ASGD — exact."""
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(3, 60, seed=2, hetero=1.0)
        tr_a = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, 3,
                                      lr=0.05)
        tr_d = async_sim.AsyncTrainer(make_strategy("dgs_plain", density=1.0),
                                      grad_fn, 3, lr=0.05)
        fa, _, ha = tr_a.run(params0, sched, batch_fn)
        fd, _, hd = tr_d.run(params0, sched, batch_fn)
        for a, d in zip(jax.tree.leaves(fa), jax.tree.leaves(fd)):
            np.testing.assert_allclose(a, d, atol=1e-5)
        np.testing.assert_allclose(ha.losses, hd.losses, atol=1e-5)

    def test_dgs_sam_density1_matches_msgd_single_worker(self):
        """One worker, no sparsification: DGS+SAMomentum == single-node
        momentum SGD stepping on the same batches."""
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = np.zeros(30, dtype=np.int32)  # single worker
        m = 0.7
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=1.0, momentum=m), grad_fn, 1,
            lr=0.05)
        fd, _, _ = tr.run(params0, sched, batch_fn)
        batches = [batch_fn(e, 0) for e in range(30)]
        fm, _ = async_sim.run_msgd(params0, grad_fn, batches, lr=0.05,
                                   momentum=m)
        for a, b in zip(jax.tree.leaves(fd), jax.tree.leaves(fm)):
            np.testing.assert_allclose(a, b, atol=1e-4)

    def test_sparse_dgs_converges(self):
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(4, 300, seed=3, hetero=0.8)
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.125, momentum=0.5), grad_fn, 4,
            lr=0.05)
        _, _, hist = tr.run(params0, sched, batch_fn)
        assert hist.losses[-20:].mean() < 0.05 * hist.losses[:5].mean()

    def test_sparse_comm_is_smaller(self):
        grad_fn, batch_fn = _problem()
        params0 = _params()
        sched = async_sim.make_schedule(4, 40, seed=4)
        dense = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, 4,
                                       lr=0.05)
        sparse = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.1, momentum=0.7), grad_fn, 4,
            lr=0.05)
        _, _, hd = dense.run(params0, sched, batch_fn)
        _, _, hs = sparse.run(params0, sched, batch_fn)
        # measured wire framing (envelope + per-leaf headers) dominates on
        # this 27-parameter toy, so the ratio is looser than the asymptotic
        # ~2*density; test_system checks the realistic-size ratio
        assert hs.up_bytes < 0.5 * hd.up_bytes
        assert hs.down_bytes < hd.down_bytes


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(5, 40), st.integers(0, 2 ** 31))
def test_property_difference_tracking_reconstructs(n_workers, n_events,
                                                   seed):
    """Whatever the schedule, theta_0 + M always equals the serially-applied
    sum of received updates (difference tracking loses nothing)."""
    grad_fn, batch_fn = _problem(seed % 97)
    params0 = _params()
    sched = async_sim.make_schedule(n_workers, n_events, seed=seed % 1000,
                                    hetero=1.0)
    tr = async_sim.AsyncTrainer(make_strategy("dgs", density=0.2),
                                grad_fn, n_workers, lr=0.02)
    final, sstate, _ = tr.run(params0, sched, batch_fn)
    # M must equal final - theta0 exactly
    model = ps.global_model(params0, sstate)
    for a, b in zip(jax.tree.leaves(model), jax.tree.leaves(final)):
        np.testing.assert_allclose(a, b, atol=1e-6)
