"""SAMomentum semantics: paper Eq. (11)/(12) and the Eq. (13)/(14)
equivalence theorem (sparsification == per-parameter enlarged batch)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core import samomentum


def test_eq12_semantics():
    """After one step, sent coords hold m*u+lr*g; unsent hold (m*u+lr*g)/m."""
    m, lr, k = 0.7, 0.1, 2
    u0 = jnp.asarray([1.0, -0.05, 0.02, 2.0])
    g = jnp.asarray([0.5, 0.01, -0.01, -0.3])
    msg, u1 = samomentum.leaf_update(u0, g, momentum=m, lr=lr, k=k)
    uacc = m * u0 + lr * g
    sent = set(np.asarray(msg.indices).tolist())
    assert sent == {0, 3}  # largest |uacc|
    for i in range(4):
        if i in sent:
            np.testing.assert_allclose(u1[i], uacc[i], rtol=1e-6)
        else:
            np.testing.assert_allclose(u1[i], uacc[i] / m, rtol=1e-6)
    # message carries the full velocity of sent coords (with lr baked in)
    for i, v in zip(np.asarray(msg.indices), np.asarray(msg.values)):
        np.testing.assert_allclose(v, uacc[i], rtol=1e-6)


def test_telescoping_theorem():
    """Eq. (13): if a coordinate stays below threshold for T-1 steps and is
    sent at step T, its sent value equals m*u_c + lr * sum(grads) — vanilla
    momentum with batch (and lr) enlarged T-fold (Eq. 14)."""
    m, lr, T = 0.7, 0.05, 6
    rng = np.random.default_rng(0)
    # coordinate 0: tiny grads then huge; coordinate 1: always huge (sent)
    grads = [jnp.asarray([0.01 * rng.standard_normal(), 5.0]) for _ in
             range(T - 1)]
    grads.append(jnp.asarray([100.0, 5.0]))
    u = jnp.asarray([0.3, 0.0])
    u_c = u[0]
    for t, g in enumerate(grads):
        msg, u = samomentum.leaf_update(u, g, momentum=m, lr=lr, k=1)
        sent = np.asarray(msg.indices).tolist()
        if t < T - 1:
            assert sent == [1]   # coordinate 0 unsent
        else:
            assert sent == [0]   # finally sent
            expected = m * u_c + lr * sum(float(g[0]) for g in grads)
            np.testing.assert_allclose(float(msg.values[0]), expected,
                                       rtol=1e-5)


def test_density_one_is_heavy_ball():
    """k = size -> every coordinate sent every step == vanilla momentum."""
    m, lr = 0.9, 0.1
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (16,))
    v = u
    for i in range(5):
        g = jax.random.normal(jax.random.fold_in(key, i), (16,))
        msg, u = samomentum.leaf_update(u, g, momentum=m, lr=lr, k=16)
        v = m * v + lr * g   # heavy ball
        np.testing.assert_allclose(
            np.sort(np.asarray(msg.values)), np.sort(np.asarray(v)),
            rtol=1e-5)
        np.testing.assert_allclose(u, v, rtol=1e-5)


def test_no_residual_buffer():
    """SAMomentum state is exactly one velocity pytree (memory win vs DGC)."""
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    state = samomentum.init(params)
    leaves = jax.tree.leaves(state)
    assert sum(l.size for l in leaves) == 8 * 8 + 8


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.floats(0.3, 0.99), st.integers(0, 2 ** 31))
def test_property_unsent_amplification(n, m, seed):
    """Unsent coordinates are exactly divided by m (so the next step's m*
    decay cancels): u_new * m == u_acc on unsent coords."""
    key = jax.random.PRNGKey(seed)
    u0 = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    k = max(1, n // 4)
    msg, u1 = samomentum.leaf_update(u0, g, momentum=m, lr=0.1, k=k)
    uacc = m * u0 + 0.1 * g
    sent = np.zeros(n, bool)
    sent[np.asarray(msg.indices)] = True
    np.testing.assert_allclose(np.where(sent, u1, u1 * m), uacc, rtol=2e-4)
