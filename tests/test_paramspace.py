"""Flat parameter arena (DESIGN.md §8): pack/unpack round-trips, and the
global-COO select/receive/commit/apply pipeline is bit-equal to the
pre-arena per-leaf path across every engine and quantize mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import engine as E
from repro.core import server as ps
from repro.core.engine import CompressionSpec
from repro.core.paramspace import ParamSpace
from repro.core.sparsify import SparseLeaf, density_to_k

MODES = ("none", "bf16", "int8", "tern")


def _random_tree(seed: int, n_leaves: int):
    """A pytree with varied ranks/shapes (dict ordering = leaves order)."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(n_leaves):
        rank = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(1, 7)) for _ in range(rank))
        tree[f"p{i:02d}"] = jnp.asarray(
            rng.normal(size=shape), jnp.float32)
    return tree


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(0, 2 ** 31))
def test_property_pack_unpack_roundtrip(n_leaves, seed):
    """unpack(pack(tree)) is the identity (bitwise) on arbitrary pytrees,
    and the layout invariants hold (offsets = running sum, views = leaves)."""
    tree = _random_tree(seed, n_leaves)
    space = ParamSpace.from_tree(tree)
    flat = space.pack(tree)
    assert flat.shape == (space.total,)
    assert space.total == sum(space.sizes)
    assert space.offsets == tuple(
        int(o) for o in np.cumsum((0,) + space.sizes[:-1]))
    out = space.unpack(flat)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # views are exactly the flattened leaves
    for v, leaf in zip(space.views(flat), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(leaf).reshape(-1))


def test_pack_roundtrip_preserves_dtype_and_scalar_leaves():
    tree = {"s": jnp.float32(3.5), "w": jnp.ones((2, 3), jnp.bfloat16)}
    space = ParamSpace.from_tree(tree)
    out = space.unpack(space.pack(tree))
    assert jnp.asarray(out["s"]).dtype == jnp.float32
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    assert float(out["s"]) == 3.5


def _perleaf_select(space, x, ks, spec):
    """The pre-arena per-leaf selection path, verbatim: engine select per
    flattened leaf (quantization per leaf)."""
    return [E.select(v, k, spec) for v, k in zip(space.views(x), ks)]


@pytest.mark.parametrize("engine_name,extra", [
    ("exact", {}),
    ("sampled", {"sample_size": 32}),
    ("blockwise", {}),
])
@pytest.mark.parametrize("mode", MODES)
def test_arena_select_bitequal_to_perleaf(engine_name, extra, mode,
                                          density=0.2):
    """ParamSpace.select == concat(per-leaf engine select), indices rebased
    by leaf offset — for every engine and quantize mode, bit-for-bit."""
    tree = _random_tree(7, 4)
    space = ParamSpace.from_tree(tree)
    x = space.pack(jax.tree.map(
        lambda l: jax.random.normal(jax.random.PRNGKey(l.size), l.shape),
        tree))
    spec = CompressionSpec(engine=engine_name, quantize=mode, **extra)
    ks = space.ks(density)
    arena = space.select(x, ks, spec)
    per = _perleaf_select(space, x, ks, spec)
    np.testing.assert_array_equal(
        np.asarray(arena.values),
        np.concatenate([np.asarray(m.values) for m in per]))
    np.testing.assert_array_equal(
        np.asarray(arena.indices),
        np.concatenate([np.asarray(m.indices) + off
                        for m, off in zip(per, space.offsets)]))
    assert arena.size == space.total
    # split() is the inverse view
    for back, m in zip(space.split(arena, ks), per):
        np.testing.assert_array_equal(np.asarray(back.values),
                                      np.asarray(m.values))
        np.testing.assert_array_equal(np.asarray(back.indices),
                                      np.asarray(m.indices))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(5, 60), st.integers(0, 2 ** 31))
def test_property_receive_commit_apply_bitequal_perleaf(n_leaves, steps,
                                                        seed):
    """The fused single-scatter server ops (receive / send_commit /
    apply_update) reproduce the per-leaf scatter path bit-for-bit over an
    arbitrary message stream."""
    rng = np.random.default_rng(seed)
    tree = _random_tree(seed % 1000, n_leaves)
    space = ParamSpace.from_tree(tree)
    total = space.total
    state = ps.init(tree, n_workers=2)
    theta = space.pack(tree)
    # per-leaf references as plain numpy
    M_ref = np.zeros(total, np.float32)
    v_ref = np.zeros((2, total), np.float32)
    theta_ref = np.asarray(theta).copy()
    for t in range(steps % 7 + 2):
        # random global-COO message built from per-leaf selections
        vals, idxs = [], []
        for off, size in zip(space.offsets, space.sizes):
            k = int(rng.integers(1, size + 1))
            idx = rng.choice(size, k, replace=False).astype(np.int32)
            val = rng.normal(size=k).astype(np.float32)
            vals.append(val)
            idxs.append(idx + off)
        msg = SparseLeaf(values=jnp.asarray(np.concatenate(vals)),
                         indices=jnp.asarray(np.concatenate(idxs)),
                         size=total)
        wid = t % 2
        state = ps.receive(state, msg)
        G = ps.send_select(state, wid, secondary_density=0.3)
        state = ps.send_commit(state, wid, G)
        theta = ps.apply_update(theta, msg)
        # per-leaf reference: one scatter per leaf (the pre-arena path)
        for off, size, val, gidx in zip(space.offsets, space.sizes, vals,
                                        idxs):
            lidx = gidx - off
            np.subtract.at(M_ref[off:off + size], lidx, val)
            np.add.at(theta_ref[off:off + size], lidx, val)
        diff = M_ref - v_ref[wid]
        for off, size in zip(space.offsets, space.sizes):
            kk = density_to_k(size, 0.3)
            leaf = E.select(jnp.asarray(diff[off:off + size]), kk,
                            CompressionSpec(engine="exact"))
            np.add.at(v_ref[wid], np.asarray(leaf.indices) + off,
                      np.asarray(leaf.values))
    np.testing.assert_array_equal(np.asarray(state.M), M_ref)
    np.testing.assert_array_equal(np.asarray(state.v), v_ref)
    np.testing.assert_array_equal(np.asarray(theta), theta_ref)


def test_dense_commit_snaps_v_to_M():
    """A dense downward message must set v_k = M exactly (no cancellation
    through v + (M - v))."""
    tree = _random_tree(3, 3)
    space = ParamSpace.from_tree(tree)
    state = ps.init(tree, n_workers=1)
    msg = SparseLeaf(
        values=jnp.asarray(np.random.default_rng(0).normal(
            size=5).astype(np.float32)),
        indices=jnp.asarray(np.arange(5, dtype=np.int32)),
        size=space.total)
    state = ps.receive(state, msg)
    G = ps.send_select(state, 0, secondary_density=None)
    assert not isinstance(G, SparseLeaf)
    state = ps.send_commit(state, 0, G)
    np.testing.assert_array_equal(np.asarray(state.v[0]),
                                  np.asarray(state.M))


def test_space_is_static_and_hashable():
    """ParamSpace rides inside jitted ServerState as a static pytree node:
    equal trees give equal (hashable) descriptors and zero jit leaves."""
    a = ParamSpace.from_tree({"w": jnp.zeros((3, 2)), "b": jnp.zeros((4,))})
    b = ParamSpace.from_tree({"w": jnp.ones((3, 2)), "b": jnp.ones((4,))})
    assert a == b and hash(a) == hash(b)
    leaves, treedef = jax.tree.flatten(a)
    assert leaves == []  # static: no traced children

    @jax.jit
    def f(state):
        return state.space.total + jnp.sum(state.M)

    state = ps.init({"w": jnp.zeros((3, 2)), "b": jnp.zeros((4,))}, 1)
    assert int(f(state)) == 10
