"""Model-family correctness: forward/loss health and exact decode
continuation (prefill+decode == full forward) for every block family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (MLAConfig, ModelConfig, MoEConfig, SSMConfig,
                          decode_step, forward, init_params, loss_fn,
                          prefill)

BASE = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=128,
                   n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                   head_dim=32, compute_dtype="float32")

FAMILIES = {
    "dense": BASE,
    "dense_bias": dataclasses.replace(BASE, qkv_bias=True),
    "partial_rotary": dataclasses.replace(BASE, rotary_pct=0.5),
    "sliding": dataclasses.replace(BASE, attention="sliding", window=8),
    "local_global": dataclasses.replace(
        BASE, attention="local_global", local_global_ratio=1, window=8,
        rope_theta_local=10000.0),
    "mrope": dataclasses.replace(BASE, rope="mrope"),
    "vlm": dataclasses.replace(BASE, rope="mrope", arch_type="vlm",
                               frontend="vision", frontend_tokens=16),
    "audio_sinusoidal": dataclasses.replace(
        BASE, rope="none", arch_type="audio", frontend="audio",
        frontend_tokens=8, norm="layernorm", activation="gelu"),
    "mla": dataclasses.replace(
        BASE, attention="mla",
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32)),
    "moe_dense": dataclasses.replace(
        BASE, arch_type="moe",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, impl="dense")),
    "moe_capacity": dataclasses.replace(
        BASE, arch_type="moe",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, impl="capacity",
                      capacity_factor=4.0)),
    "ssm": dataclasses.replace(
        BASE, arch_type="ssm", attention="none", rope="none", d_ff=0,
        ssm=SSMConfig(d_state=16, head_dim=32, chunk=8)),
    "hybrid_shared": dataclasses.replace(
        BASE, arch_type="hybrid", attn_every=2, shared_attention=True,
        ssm=SSMConfig(d_state=16, head_dim=32, chunk=8)),
    "tied": dataclasses.replace(BASE, tie_embeddings=True),
}


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), cfg.cdtype)
    return batch


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_forward_loss_grad_finite(family):
    cfg = FAMILIES[family]
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 10.0      # ~ln(256)=5.5 at init
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_decode_continuation_matches_forward(family):
    cfg = FAMILIES[family]
    atol = 3e-3 if cfg.ssm is not None else 1e-4
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    lf, _ = forward(params, tokens, cfg, frontend_embeds=fe)
    _, caches, _ = prefill(params, tokens[:, :-1], cfg, frontend_embeds=fe,
                           max_len=tokens.shape[1])
    ld, _ = decode_step(params, caches, tokens[:, -1:], jnp.int32(31), cfg)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(lf[:, -1]),
                               atol=atol)


def test_multistep_decode_matches_forward():
    """Roll 4 decode steps; logits must track the full forward pass."""
    cfg = FAMILIES["sliding"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg)["tokens"]
    lf, _ = forward(params, tokens, cfg)
    _, caches, _ = prefill(params, tokens[:, :28], cfg, max_len=32)
    for t in range(28, 32):
        ld, caches = decode_step(params, caches, tokens[:, t:t + 1],
                                 jnp.int32(t), cfg)
        if t < 31:
            np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                       np.asarray(lf[:, t]), atol=1e-4)


def test_moe_capacity_matches_dense_when_no_drops():
    """With generous capacity, sort-based dispatch == exact dense MoE."""
    cd = FAMILIES["moe_dense"]
    cc = dataclasses.replace(
        cd, moe=dataclasses.replace(cd.moe, impl="capacity",
                                    capacity_factor=8.0))
    params = init_params(jax.random.PRNGKey(0), cd)
    tokens = _batch(cd)["tokens"]
    ld, _ = forward(params, tokens, cd)
    lc, _ = forward(params, tokens, cc)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc), atol=2e-4)


def test_remat_matches_norematerialization():
    cfg = FAMILIES["dense"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    g1 = jax.grad(lambda p: loss_fn(p, batch, cfg, remat=False)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, batch, cfg, remat=True)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive per-step recurrence."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 16, 3, 4, 5
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, 1, N))
    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    # sequential reference
    s = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        Bt = np.repeat(np.asarray(Bm[:, t]), H, axis=1)       # (B,H,N)
        Ct = np.repeat(np.asarray(Cm[:, t]), H, axis=1)
        s = s * decay[..., None, None] + xdt[..., None] * Bt[:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", s, Ct)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), s, atol=1e-4)
