import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, make_strategy
from repro.core.paramspace import ParamSpace
from repro.core.sparsify import SparseLeaf, sparse_to_dense


def _grads():
    key = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(key, (10, 10)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5,))}


def _params():
    return {"w": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}


def _space():
    return ParamSpace.from_tree(_params())


def test_asgd_dense_message():
    s = make_strategy("asgd")
    st0 = s.init(_params())
    _, msg = s.step(st0, _grads(), lr=0.1)
    assert not isinstance(msg, SparseLeaf)
    space = _space()
    assert msg.shape == (space.total,)
    # leaves order is alphabetical: the first view is "b"
    np.testing.assert_allclose(np.asarray(space.views(msg)[0]),
                               0.1 * np.asarray(_grads()["b"]).reshape(-1),
                               rtol=1e-6)


def test_message_seg_matches_per_leaf_ks():
    space = _space()
    s = make_strategy("dgs", density=0.03)
    # leaves order alphabetical: b (5,), then w (100,)
    assert s.message_seg(space) == (max(1, round(0.03 * 5)),
                                    max(1, round(0.03 * 100)))
    assert make_strategy("asgd").message_seg(space) is None


def test_gd_residual_bookkeeping():
    """GD: residual + message == accumulated lr*grads at every step."""
    s = make_strategy("gd_async", density=0.05)
    space = _space()
    st = s.init(_params())
    acc = np.zeros(space.total)
    for t in range(4):
        g = jax.tree.map(lambda x: x * (t + 1), _grads())
        st, msg = s.step(st, g, lr=0.1)
        acc += 0.1 * np.asarray(space.pack(g))
        sent = np.asarray(sparse_to_dense(msg))
        resid = np.asarray(st.inner)
        assert resid.shape == (space.total,)
        np.testing.assert_allclose(sent + resid, acc, rtol=1e-5)
        acc -= sent


def test_dgc_momentum_masking():
    """DGC zeroes velocity AND residual on sent (global) coordinates."""
    s = make_strategy("dgc_async", density=0.05, momentum=0.9)
    st = s.init(_params())
    st, msg = s.step(st, _grads(), lr=0.1)
    idx = np.asarray(msg.indices)
    assert np.all(np.asarray(st.inner.velocity)[idx] == 0.0)
    assert np.all(np.asarray(st.inner.residual)[idx] == 0.0)


def test_dgc_clipping():
    s = make_strategy("dgc_async", density=1.0, clip_norm=0.001)
    st = s.init(_params())
    _, msg = s.step(st, _grads(), lr=1.0)
    total = np.sqrt(float(jnp.sum(msg.values ** 2)))
    assert total <= 0.001 + 1e-6


def test_dgs_message_k_sizes():
    s = make_strategy("dgs", density=0.03)
    space = _space()
    st = s.init(_params())
    _, msg = s.step(st, _grads(), lr=0.1)
    seg = s.message_seg(space)
    # one global-index message, k == sum of per-tensor ks
    assert isinstance(msg, SparseLeaf)
    assert msg.size == space.total
    assert msg.k == sum(seg)
    # per-leaf views recover the per-tensor selections
    parts = space.split(msg, seg)
    assert [p.k for p in parts] == [max(1, round(0.03 * 5)),
                                    max(1, round(0.03 * 100))]
    for p, size in zip(parts, space.sizes):
        assert np.all(np.asarray(p.indices) >= 0)
        assert np.all(np.asarray(p.indices) < size)


def test_unknown_strategy():
    with pytest.raises(ValueError):
        make_strategy("nope")


def test_msgd_step():
    p, u = _params(), jax.tree.map(jnp.zeros_like, _params())
    g = _grads()
    p2, u2 = baselines.msgd_step(p, u, g, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(u2["b"], 0.1 * g["b"], rtol=1e-6)
    np.testing.assert_allclose(p2["b"], -0.1 * g["b"], rtol=1e-6)
