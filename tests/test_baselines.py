import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, make_strategy
from repro.core.sparsify import SparseLeaf, sparse_to_dense


def _grads():
    key = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(key, (10, 10)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5,))}


def _params():
    return {"w": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}


def test_asgd_dense_message():
    s = make_strategy("asgd")
    st0 = s.init(_params())
    _, msg = s.step(st0, _grads(), lr=0.1)
    assert all(not isinstance(m, SparseLeaf) for m in msg)
    # leaves order is alphabetical: msg[0] == "b"
    np.testing.assert_allclose(msg[0], 0.1 * _grads()["b"], rtol=1e-6)


def test_gd_residual_bookkeeping():
    """GD: residual + message == accumulated lr*grads at every step."""
    s = make_strategy("gd_async", density=0.05)
    st = s.init(_params())
    acc = {k: np.zeros(v.size) for k, v in _params().items()}
    for t in range(4):
        g = jax.tree.map(lambda x: x * (t + 1), _grads())
        st, msg = s.step(st, g, lr=0.1)
        for key_i, (k, v) in enumerate(sorted(_params().items())):
            acc[k] += 0.1 * np.asarray(jax.tree.leaves(g)[key_i]).reshape(-1)
        sent = [np.asarray(sparse_to_dense(m)) for m in msg]
        resid = [np.asarray(r) for r in jax.tree.leaves(st.inner)]
        for i, k in enumerate(sorted(acc)):
            np.testing.assert_allclose(sent[i] + resid[i], acc[k], rtol=1e-5)
            acc[k] -= sent[i]


def test_dgc_momentum_masking():
    """DGC zeroes velocity AND residual on sent coordinates."""
    s = make_strategy("dgc_async", density=0.05, momentum=0.9)
    st = s.init(_params())
    st, msg = s.step(st, _grads(), lr=0.1)
    for m, u, r in zip(msg, jax.tree.leaves(st.inner.velocity),
                       jax.tree.leaves(st.inner.residual)):
        idx = np.asarray(m.indices)
        assert np.all(np.asarray(u)[idx] == 0.0)
        assert np.all(np.asarray(r)[idx] == 0.0)


def test_dgc_clipping():
    s = make_strategy("dgc_async", density=1.0, clip_norm=0.001)
    st = s.init(_params())
    _, msg = s.step(st, _grads(), lr=1.0)
    total = np.sqrt(sum(float(jnp.sum(m.values ** 2)) for m in msg))
    assert total <= 0.001 + 1e-6


def test_dgs_message_k_sizes():
    s = make_strategy("dgs", density=0.03)
    st = s.init(_params())
    _, msg = s.step(st, _grads(), lr=0.1)
    # leaves order alphabetical: b (5,), then w (100,)
    assert msg[0].k == max(1, round(0.03 * 5))
    assert msg[1].k == max(1, round(0.03 * 100))


def test_unknown_strategy():
    with pytest.raises(ValueError):
        make_strategy("nope")


def test_msgd_step():
    p, u = _params(), jax.tree.map(jnp.zeros_like, _params())
    g = _grads()
    p2, u2 = baselines.msgd_step(p, u, g, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(u2["b"], 0.1 * g["b"], rtol=1e-6)
    np.testing.assert_allclose(p2["b"], -0.1 * g["b"], rtol=1e-6)
