"""Wire codec: round-trip exactness, quantize semantics, size accounting."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.cluster import wire
from repro.core.sparsify import SparseLeaf, quantize_dequantize, topk_select

MODES = ("none", "bf16", "int8", "tern")

# the repo always executes quantize_dequantize under jit (inside engine /
# strategy jits, and as the codec's _quantize_parts); XLA's fused
# evaluation can differ from eager by 1 ulp (e.g. FMA-contracted
# `max/127 + 1e-12`), so bitwise equivalence is pinned to the jitted form
# and eager gets an allclose-at-1-ulp check
_qd_jit = {m: jax.jit(partial(quantize_dequantize, mode=m)) for m in MODES}


def _assert_matches_quantize_dequantize(dec_values, raw_values, mode):
    ref_jit = _qd_jit[mode](raw_values)[0]
    np.testing.assert_array_equal(np.asarray(dec_values),
                                  np.asarray(ref_jit))
    ref_eager = quantize_dequantize(raw_values, mode)[0]
    np.testing.assert_allclose(np.asarray(dec_values),
                               np.asarray(ref_eager), rtol=3e-7, atol=0)


def _leaf(n, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    return topk_select(x, k)


class TestLeafRoundTrip:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("n,k", [(8, 1), (300, 7), (70000, 33),
                                     (50, 50)])
    def test_sparse_roundtrip_exact_vs_quantize_dequantize(self, mode, n, k):
        """decode(encode(v, mode)) == quantize_dequantize(v, mode) bitwise,
        and equals the `shipped` leaf the encoder hands back."""
        leaf = _leaf(n, k, seed=n + k)
        frame, shipped = wire.encode_leaf(5, leaf, mode)
        leaf_id, dec, end = wire.decode_leaf(frame)
        assert leaf_id == 5 and end == len(frame)
        _assert_matches_quantize_dequantize(dec.values, leaf.values, mode)
        np.testing.assert_array_equal(np.asarray(dec.values),
                                      np.asarray(shipped.values))
        np.testing.assert_array_equal(np.asarray(dec.indices),
                                      np.asarray(leaf.indices))
        assert dec.size == n

    @pytest.mark.parametrize("mode", MODES)
    def test_frame_size_matches_accounting(self, mode):
        for n, k in [(8, 3), (300, 7), (70000, 128)]:
            leaf = _leaf(n, k, seed=1)
            frame, _ = wire.encode_leaf(0, leaf, mode)
            assert len(frame) == wire.leaf_frame_bytes(k, n, mode)

    def test_index_width_narrows_with_size(self):
        # u8 for <=256, u16 for <=65536, u32 beyond — derived from `size`
        assert wire.index_dtype(256) == np.uint8
        assert wire.index_dtype(257) == np.uint16
        assert wire.index_dtype(1 << 16) == np.uint16
        assert wire.index_dtype((1 << 16) + 1) == np.uint32
        small = wire.leaf_frame_bytes(10, 200, "none")
        big = wire.leaf_frame_bytes(10, 1 << 20, "none")
        assert big - small == 10 * 3  # 3 extra index bytes per entry

    @pytest.mark.parametrize("nnz_frac", [0.0, 0.05, 0.5, 1.0])
    def test_dense_roundtrip_exact(self, nnz_frac):
        rng = np.random.default_rng(3)
        d = np.where(rng.random(400) < nnz_frac,
                     rng.normal(size=400), 0.0).astype(np.float32)
        frame, shipped = wire.encode_leaf(1, jnp.asarray(d), "none")
        _, dec, end = wire.decode_leaf(frame)
        assert end == len(frame)
        np.testing.assert_array_equal(np.asarray(dec), d)
        np.testing.assert_array_equal(np.asarray(shipped), d)
        # codec picked the cheaper dense encoding, and accounted it exactly
        nnz = int(np.count_nonzero(d))
        assert len(frame) == wire.leaf_frame_bytes(
            nnz, 400, "none", wire._dense_kind(nnz, 400))

    def test_tern_packs_four_codes_per_byte(self):
        leaf = _leaf(1000, 100, seed=2)
        f_tern, _ = wire.encode_leaf(0, leaf, "tern")
        f_none, _ = wire.encode_leaf(0, leaf, "none")
        # 100 f32 values (400B) become 25 code bytes + 4B scale
        assert len(f_none) - len(f_tern) == 400 - 25 - 4


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2000), st.integers(1, 64), st.integers(0, 2 ** 31))
def test_property_roundtrip_all_modes(n, k, seed):
    k = min(k, n)
    leaf = _leaf(n, k, seed)
    for mode in MODES:
        frame, shipped = wire.encode_leaf(0, leaf, mode)
        assert len(frame) == wire.leaf_frame_bytes(k, n, mode)
        _, dec, _ = wire.decode_leaf(frame)
        _assert_matches_quantize_dequantize(dec.values, leaf.values, mode)
        np.testing.assert_array_equal(np.asarray(dec.values),
                                      np.asarray(shipped.values))
        np.testing.assert_array_equal(np.asarray(dec.indices),
                                      np.asarray(leaf.indices))


def _arena_leaf(sizes, density, seed):
    """A segmented global-index arena message like the runtime ships."""
    rng = np.random.default_rng(seed)
    offs = np.cumsum([0] + list(sizes[:-1]))
    total = int(sum(sizes))
    vals, idxs, seg = [], [], []
    for off, size in zip(offs, sizes):
        k = max(1, int(round(size * density)))
        idxs.append(rng.choice(size, k, replace=False).astype(np.int32)
                    + off)
        vals.append(rng.normal(size=k).astype(np.float32))
        seg.append(k)
    leaf = SparseLeaf(values=jnp.asarray(np.concatenate(vals)),
                      indices=jnp.asarray(np.concatenate(idxs)),
                      size=total)
    return leaf, tuple(seg)


class TestArenaFrame:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("sizes", [(40,), (200, 31, 4000),
                                       (70000, 9, 300)])
    def test_arena_roundtrip_segmentwise_quantize(self, mode, sizes):
        """decode(encode_arena) reproduces the SEGMENT-wise jitted
        quantizer bitwise (one scale per tensor) and the ``shipped`` leaf,
        with one header + one index block + one value block."""
        leaf, seg = _arena_leaf(sizes, 0.1, seed=sum(sizes))
        frame, shipped = wire.encode_arena_leaf(leaf, mode, seg)
        assert len(frame) == wire.arena_frame_bytes(seg, leaf.size, mode)
        leaf_id, dec, end = wire.decode_leaf(frame)
        assert end == len(frame)
        # per-segment bit-equality against the jitted quantizer
        off = 0
        for s in seg:
            _assert_matches_quantize_dequantize(
                dec.values[off:off + s], leaf.values[off:off + s], mode)
            off += s
        np.testing.assert_array_equal(np.asarray(dec.values),
                                      np.asarray(shipped.values))
        np.testing.assert_array_equal(np.asarray(dec.indices),
                                      np.asarray(leaf.indices))
        assert dec.size == leaf.size

    @pytest.mark.parametrize("mode", MODES)
    def test_arena_matches_quantize_message(self, mode):
        """The in-process stand-in (async_sim / scan runner path) ==
        what the codec ships over the wire."""
        leaf, seg = _arena_leaf((128, 40), 0.2, seed=3)
        _, shipped = wire.encode_arena_leaf(leaf, mode, seg)
        local = wire.quantize_message(leaf, mode, seg=seg)
        np.testing.assert_array_equal(np.asarray(shipped.values),
                                      np.asarray(local.values))

    def test_arena_beats_perleaf_framing(self):
        """One arena frame costs less than the per-leaf frames it fuses:
        a 4-byte seg entry replaces each 16-byte leaf header (the arena's
        global indices can cost one extra byte per entry on tiny leaves,
        but header savings dominate at matched index widths)."""
        sizes = (500, 300, 290, 450, 310)   # all u16, total still u16
        leaf, seg = _arena_leaf(sizes, 0.1, seed=5)
        arena = wire.arena_frame_bytes(seg, leaf.size, "none")
        perleaf = sum(wire.leaf_frame_bytes(k, size, "none")
                      for k, size in zip(seg, sizes))
        assert arena < perleaf

    def test_message_roundtrip_with_arena_seg(self):
        leaf, seg = _arena_leaf((64, 1000), 0.1, seed=9)
        payload, shipped = wire.encode_message(
            wire.UP, 2, 5, [leaf], mode="int8", seg=seg, aux=1.5)
        assert len(payload) == wire.frame_bytes(leaf, mode="int8", seg=seg)
        m = wire.decode_message(payload)
        assert (m.type, m.sender, m.seq, m.aux) == (wire.UP, 2, 5, 1.5)
        assert len(m.leaves) == 1
        np.testing.assert_array_equal(np.asarray(m.leaves[0].values),
                                      np.asarray(shipped[0].values))
        np.testing.assert_array_equal(np.asarray(m.leaves[0].indices),
                                      np.asarray(leaf.indices))


class TestMessage:
    def test_envelope_and_multi_leaf(self):
        msgs = [_leaf(100, 5, 0), jnp.zeros(64),
                _leaf(300, 2, 1)]
        payload, shipped = wire.encode_message(
            wire.UP, 3, 17, msgs, mode="int8", aux=2.5)
        assert len(payload) == wire.frame_bytes(msgs, mode="int8")
        m = wire.decode_message(payload)
        assert (m.type, m.sender, m.seq, m.aux) == (wire.UP, 3, 17, 2.5)
        assert len(m.leaves) == 3
        for dec, ship in zip(m.leaves, shipped):
            if isinstance(ship, SparseLeaf):
                np.testing.assert_array_equal(np.asarray(dec.values),
                                              np.asarray(ship.values))
            else:
                np.testing.assert_array_equal(np.asarray(dec),
                                              np.asarray(ship))

    def test_control_messages(self):
        for t in (wire.HELLO, wire.WELCOME, wire.SKIP, wire.BYE):
            payload, _ = wire.encode_message(t, 9, 4)
            m = wire.decode_message(payload)
            assert (m.type, m.sender, m.seq, m.leaves) == (t, 9, 4, [])

    def test_quantize_message_matches_encode_shipped(self):
        """async_sim's in-process stand-in == what the codec ships."""
        msgs = [_leaf(128, 9, 7), _leaf(40, 3, 8)]
        for mode in MODES:
            _, shipped = wire.encode_message(wire.UP, 0, 0, msgs, mode=mode)
            local = wire.quantize_message(msgs, mode)
            for a, b in zip(shipped, local):
                np.testing.assert_array_equal(np.asarray(a.values),
                                              np.asarray(b.values))


# ------------------------------------------- fused quantize+pack kernel

class TestPackFromArena:
    """wire.pack_from_arena (the fused kernels/wire_pack.py path) against
    the legacy per-segment encoder it replaced."""

    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("sizes", [(37, 400, 63), (5, 3), (1000,)])
    def test_frames_byte_identical_to_segment_encoder(self, mode, sizes):
        size = 70000
        k = sum(sizes)
        rng = np.random.default_rng(hash((mode, sizes)) % 2 ** 31)
        leaf = SparseLeaf(
            values=jnp.asarray(rng.normal(size=k).astype(np.float32)),
            indices=jnp.asarray(np.sort(rng.choice(size, k, replace=False))
                                .astype(np.int32)),
            size=size)
        legacy, ship_legacy = wire.encode_arena_leaf_segments(
            leaf, mode, sizes)
        fused, ship_fused = wire.pack_from_arena(leaf, mode, sizes)
        assert fused == legacy                      # byte-for-byte frame
        np.testing.assert_array_equal(np.asarray(ship_fused.values),
                                      np.asarray(ship_legacy.values))
        np.testing.assert_array_equal(np.asarray(ship_fused.indices),
                                      np.asarray(ship_legacy.indices))
        # and the frame still decodes to exactly the shipped values
        _, dec, off = wire.decode_leaf(fused)
        assert off == len(fused)
        np.testing.assert_array_equal(np.asarray(dec.values),
                                      np.asarray(ship_fused.values))

    @pytest.mark.parametrize("mode", ("bf16", "int8", "tern"))
    def test_quantize_pack_pallas_interpret_matches_xla(self, mode):
        from repro.kernels import wire_pack

        seg = (100, 30, 126)
        k = sum(seg)
        rng = np.random.default_rng(11)
        values = jnp.asarray(rng.normal(size=k).astype(np.float32))
        codes_x, scales_x, dq_x = wire_pack.quantize_pack(
            values, mode=mode, seg=seg, pallas=False)
        codes_p, scales_p, dq_p = wire_pack.quantize_pack(
            values, mode=mode, seg=seg, pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(codes_x),
                                      np.asarray(codes_p))
        np.testing.assert_array_equal(np.asarray(scales_x),
                                      np.asarray(scales_p))
        np.testing.assert_array_equal(np.asarray(dq_x), np.asarray(dq_p))

    def test_narrow_indices_widths(self):
        from repro.kernels import wire_pack

        idx = jnp.asarray([0, 17, 255], jnp.int32)
        assert wire_pack.narrow_indices(idx, size=256).dtype == jnp.uint8
        assert wire_pack.narrow_indices(idx, size=257).dtype == jnp.uint16
        assert wire_pack.narrow_indices(idx, size=1 << 17).dtype \
            == jnp.uint32
