import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import sparsify


class TestTopK:
    def test_topk_select_basic(self):
        x = jnp.asarray([0.1, -5.0, 3.0, 0.0, -0.2])
        leaf = sparsify.topk_select(x, 2)
        assert set(np.asarray(leaf.indices).tolist()) == {1, 2}
        assert leaf.size == 5

    def test_density_to_k(self):
        assert sparsify.density_to_k(1000, 0.01) == 10
        assert sparsify.density_to_k(10, 0.001) == 1   # floor of 1
        assert sparsify.density_to_k(10, 1.0) == 10
        with pytest.raises(ValueError):
            sparsify.density_to_k(10, 0.0)

    def test_threshold_matches_kth(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (503,))
        thr = sparsify.topk_threshold(x, 37)
        assert int(jnp.sum(jnp.abs(x) >= thr)) == 37

    def test_decode_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (256,))
        leaf = sparsify.topk_select(x, 19)
        dense = sparsify.sparse_to_dense(leaf)
        mask = sparsify.topk_mask(x, 19)
        np.testing.assert_allclose(dense, jnp.where(mask, x, 0.0), atol=0)

    def test_threshold_select_equals_topk(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1024,))
        k = 33
        thr = sparsify.topk_threshold(x, k)
        a = sparsify.threshold_select(x, thr, k)
        b = sparsify.topk_select(x, k)
        assert set(np.asarray(a.indices).tolist()) == \
            set(np.asarray(b.indices).tolist())

    def test_sampled_threshold_reasonable(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (1 << 16,))
        thr = sparsify.sampled_threshold(x, 0.01, sample_size=4096)
        frac = float(jnp.mean(jnp.abs(x) >= thr))
        assert 0.002 < frac < 0.05  # near 1%


class TestTree:
    def _tree(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"a": jax.random.normal(k1, (32, 16)),
                "b": jax.random.normal(k2, (100,)),
                "c": {"d": jax.random.normal(k3, (7,))}}

    def test_tree_sparsify_residual_disjoint(self):
        tree = self._tree(jax.random.PRNGKey(0))
        msgs, resid = sparsify.tree_sparsify(tree, 0.1)
        for m, leaf, r in zip(msgs, jax.tree.leaves(tree),
                              jax.tree.leaves(resid)):
            dense = sparsify.sparse_to_dense(m).reshape(leaf.shape)
            # message + residual reconstructs the original exactly
            np.testing.assert_allclose(dense + r, leaf, atol=1e-7)
            # supports are disjoint
            assert not np.any((np.asarray(dense) != 0) & (np.asarray(r) != 0))

    def test_message_bytes(self):
        tree = self._tree(jax.random.PRNGKey(1))
        msgs, _ = sparsify.tree_sparsify(tree, 0.1)
        ks = sparsify.tree_ks(tree, 0.1)
        assert sparsify.message_bytes(msgs) == sum(k * 8 for k in ks)
        assert sparsify.dense_bytes(tree) == (32 * 16 + 100 + 7) * 4


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 300), st.floats(0.01, 1.0), st.integers(0, 2 ** 31))
def test_property_k_nonzeros(n, density, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    k = sparsify.density_to_k(n, density)
    leaf = sparsify.topk_select(x, k)
    assert leaf.values.shape == (k,)
    # top-k magnitudes dominate everything not selected
    sel = set(np.asarray(leaf.indices).tolist())
    mag = np.abs(np.asarray(x))
    if len(sel) < n:
        unsel_max = max(mag[i] for i in range(n) if i not in sel)
        sel_min = min(mag[i] for i in sel)
        assert sel_min >= unsel_max - 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 200), st.integers(1, 50), st.integers(0, 2 ** 31))
def test_property_decode_preserves_values(n, k, seed):
    k = min(k, n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    leaf = sparsify.topk_select(x, k)
    dense = np.asarray(sparsify.sparse_to_dense(leaf))
    for i, v in zip(np.asarray(leaf.indices), np.asarray(leaf.values)):
        assert dense[i] == v
