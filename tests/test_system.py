"""End-to-end behaviour: the paper's headline claims at smoke scale.

These mirror EXPERIMENTS.md §Paper-validation: on the same async schedule,
(1) every sparsified strategy slashes upward communication ~10x at density
0.1, and (2) DGS converges at least as well as GD-async / plain ASGD under
staleness (the paper's Fig.1/Table III ordering; the full-strength version
runs in benchmarks/).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import async_sim, make_strategy
from repro.data.synthetic import ClassificationTask


def _mlp_problem(task):
    def init(key):
        k1, k2 = jax.random.split(key)
        h = 32
        return {
            "w1": jax.random.normal(k1, (task.n_features, h)) * 0.2,
            "b1": jnp.zeros((h,)),
            "w2": jax.random.normal(k2, (h, task.n_classes)) * 0.2,
            "b2": jnp.zeros((task.n_classes,)),
        }

    def apply(p, x):
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def grad_fn(p, batch):
        x, y = batch

        def loss(p):
            logits = apply(p, x)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(p)

    return init, apply, grad_fn


def _accuracy(apply, params, task):
    x, y = task.eval_set(256)
    pred = jnp.argmax(apply(params, x), axis=-1)
    return float(jnp.mean(pred == y))


def test_async_training_end_to_end():
    task = ClassificationTask(n_features=32, n_classes=5, batch_size=32,
                              noise=0.5, seed=0)
    init, apply, grad_fn = _mlp_problem(task)
    params0 = init(jax.random.PRNGKey(0))
    sched = async_sim.make_schedule(8, 400, seed=1, hetero=0.8)

    def batch_fn(e, k):
        return task.batch(e, worker=k)

    results = {}
    for name, kw in [("asgd", {}),
                     ("gd_async", {"density": 0.1}),
                     ("dgs", {"density": 0.1, "momentum": 0.5})]:
        tr = async_sim.AsyncTrainer(make_strategy(name, **kw), grad_fn, 8,
                                    lr=0.1)
        final, _, hist = tr.run(params0, sched, batch_fn)
        results[name] = {"acc": _accuracy(apply, final, task),
                         "up": hist.up_bytes, "loss": hist.losses}
    # everyone learns
    for name, r in results.items():
        assert r["acc"] > 0.7, (name, r["acc"])
    # sparse strategies move ~10x less data upward
    assert results["dgs"]["up"] < 0.2 * results["asgd"]["up"]
    assert results["gd_async"]["up"] < 0.2 * results["asgd"]["up"]
    # DGS with momentum at least matches the momentum-free sparsifier
    assert results["dgs"]["acc"] >= results["gd_async"]["acc"] - 0.05


def test_secondary_compression_reduces_downlink():
    task = ClassificationTask(n_features=32, n_classes=5, batch_size=32,
                              seed=0)
    init, apply, grad_fn = _mlp_problem(task)
    params0 = init(jax.random.PRNGKey(0))
    sched = async_sim.make_schedule(6, 150, seed=2, hetero=0.6)

    def batch_fn(e, k):
        return task.batch(e, worker=k)

    base = async_sim.AsyncTrainer(
        make_strategy("dgs", density=0.1, momentum=0.5), grad_fn, 6, lr=0.1)
    comp = async_sim.AsyncTrainer(
        make_strategy("dgs", density=0.1, momentum=0.5), grad_fn, 6, lr=0.1,
        secondary_density=0.05)
    _, _, hb = base.run(params0, sched, batch_fn)
    fc, _, hc = comp.run(params0, sched, batch_fn)
    assert hc.down_bytes < hb.down_bytes
    assert _accuracy(apply, fc, task) > 0.7
