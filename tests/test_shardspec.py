"""Sharded arena routing (DESIGN.md §12): ShardSpec construction,
split_by_shard/merge round trips, the ownership arithmetic shared with
core/distributed.py's shardedps exchange, and byte-exact sharded frames
across every engine x wire-quantization mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.cluster import wire
from repro.core.engine import CompressionSpec
from repro.core.paramspace import ParamSpace, ShardSpec
from repro.core.sparsify import SparseLeaf

MODES = ("none", "bf16", "int8", "tern")
ENGINES = (("exact", {}), ("sampled", {"sample_size": 32}),
           ("blockwise", {}))


def _random_tree(seed: int, n_leaves: int):
    """A pytree with varied ranks/shapes (dict ordering = leaves order)."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(n_leaves):
        rank = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(1, 7)) for _ in range(rank))
        tree[f"p{i:02d}"] = jnp.asarray(
            rng.normal(size=shape), jnp.float32)
    return tree


def _arena_message(space, seed: int, density: float = 0.5,
                   spec=CompressionSpec(engine="exact")):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(space.total,)), jnp.float32)
    seg = space.ks(density)
    return space.select(x, seg, spec), seg


def _scatter(msg, total: int) -> np.ndarray:
    dense = np.zeros(total, np.float32)
    np.add.at(dense, np.asarray(msg.indices), np.asarray(msg.values))
    return dense


# ---------------------------------------------------------------------------
# construction properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 8))
def test_property_even_partition_matches_distributed_rule(total, S):
    """even() covers [0, total) with disjoint ranges for ANY total % S,
    and its ownership equals core/distributed.py's `idx // stride`."""
    spec = ShardSpec.even(total, S)
    assert spec.total == total and spec.n_shards == S
    assert sum(spec.sizes) == total
    assert all(sz >= 0 for sz in spec.sizes)
    idx = np.arange(total)
    np.testing.assert_array_equal(
        spec.owner_of(idx), idx // ShardSpec.even_stride(total, S))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 2 ** 31))
def test_property_for_space_is_leaf_aligned(n_leaves, S, seed):
    """Every for_space bound lands on a leaf edge; the shard leaf lists
    partition the tree's leaves in order (empty shards allowed)."""
    tree = _random_tree(seed, n_leaves)
    space = ParamSpace.from_tree(tree)
    spec = ShardSpec.for_space(space, S)
    assert spec.total == space.total and spec.n_shards == S
    assert set(spec.bounds) <= set(space.offsets) | {space.total}
    leaves = jax.tree.leaves(tree)
    parts = [spec.shard_leaves(leaves, s) for s in range(S)]
    flat = [leaf for p in parts for leaf in p]
    assert len(flat) == len(leaves)
    for a, b in zip(flat, leaves):
        assert a is b
    # per-shard sizes are the summed leaf sizes — shard s IS a sub-arena
    for s, part in enumerate(parts):
        assert sum(int(np.prod(x.shape)) if x.shape else 1
                   for x in part) == spec.sizes[s]


# ---------------------------------------------------------------------------
# split/merge round trips
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 2 ** 31))
def test_property_leaf_aligned_split_merge_bitwise(n_leaves, S, seed):
    """Leaf-aligned split -> merge reproduces the message bit-for-bit in
    the ORIGINAL entry order, for uneven total % S and empty shards."""
    tree = _random_tree(seed, n_leaves)
    space = ParamSpace.from_tree(tree)
    spec = ShardSpec.for_space(space, S)
    msg, seg = _arena_message(space, seed % 2 ** 16)
    pieces = spec.split_by_shard(msg, seg)
    assert len(pieces) == S
    recon_seg = []
    for (piece, sub_seg), size in zip(pieces, spec.sizes):
        assert int(piece.size) == size
        assert int(piece.values.shape[0]) == sum(sub_seg)
        if piece.values.shape[0]:
            li = np.asarray(piece.indices)
            assert li.min() >= 0 and li.max() < size
        recon_seg.extend(sub_seg)
    assert tuple(recon_seg) == tuple(seg)
    merged = spec.merge([p for p, _ in pieces])
    assert int(merged.size) == space.total
    np.testing.assert_array_equal(np.asarray(merged.values),
                                  np.asarray(msg.values))
    np.testing.assert_array_equal(np.asarray(merged.indices),
                                  np.asarray(msg.indices))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(0, 2 ** 31))
def test_property_generic_bounds_inside_segments(n_leaves, S, seed):
    """Arbitrary bounds — including boundaries INSIDE a tensor's segment
    and empty shards — split any straddled segment into per-shard
    sub-counts; the merged message scatters to the identical dense
    update (top-k indices are unique, so order cannot matter)."""
    tree = _random_tree(seed, n_leaves)
    space = ParamSpace.from_tree(tree)
    rng = np.random.default_rng((seed % 2 ** 16) + 1)
    interior = np.sort(rng.integers(0, space.total + 1, size=S - 1))
    spec = ShardSpec(bounds=(0, *(int(b) for b in interior), space.total))
    msg, seg = _arena_message(space, seed % 2 ** 16)
    pieces = spec.split_by_shard(msg, seg)
    sub_total = np.zeros(len(seg), np.int64)
    for (piece, sub_seg), size in zip(pieces, spec.sizes):
        assert int(piece.values.shape[0]) == sum(sub_seg)
        if piece.values.shape[0]:
            li = np.asarray(piece.indices)
            assert li.min() >= 0 and li.max() < size
        sub_total += np.asarray(sub_seg)
    np.testing.assert_array_equal(sub_total, np.asarray(seg))
    merged = spec.merge([p for p, _ in pieces])
    np.testing.assert_array_equal(_scatter(merged, space.total),
                                  _scatter(msg, space.total))


def test_split_requires_matching_arena_and_seg():
    space = ParamSpace.from_tree({"w": jnp.ones((4, 3))})
    msg, seg = _arena_message(space, 0)
    with pytest.raises(ValueError):
        ShardSpec(bounds=(0, 5)).split_by_shard(msg, seg)   # wrong total
    with pytest.raises(ValueError):
        ShardSpec.for_space(space, 2).split_by_shard(msg)   # sparse, no seg


def test_more_shards_than_leaves_yields_empty_shards():
    tree = {"b": jnp.ones((3,)), "w": jnp.ones((5, 2))}
    space = ParamSpace.from_tree(tree)
    spec = ShardSpec.for_space(space, 5)
    assert spec.n_shards == 5 and sum(spec.sizes) == space.total
    assert spec.sizes.count(0) >= 3
    msg, seg = _arena_message(space, 3)
    pieces = spec.split_by_shard(msg, seg)
    for (piece, sub_seg), size in zip(pieces, spec.sizes):
        if size == 0:
            assert int(piece.values.shape[0]) == 0 and sum(sub_seg) == 0
    merged = spec.merge([p for p, _ in pieces])
    np.testing.assert_array_equal(np.asarray(merged.values),
                                  np.asarray(msg.values))
    np.testing.assert_array_equal(np.asarray(merged.indices),
                                  np.asarray(msg.indices))


def test_dense_split_merge_roundtrip():
    space = ParamSpace.from_tree(_random_tree(11, 4))
    spec = ShardSpec.even(space.total, 3)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(space.total,)),
                    jnp.float32)
    pieces = spec.split_by_shard(x)
    assert all(sub is None for _, sub in pieces)
    np.testing.assert_array_equal(
        np.asarray(spec.merge([p for p, _ in pieces])), np.asarray(x))


# ---------------------------------------------------------------------------
# engine x quantization: sharded frames == unsharded frame, byte-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("engine,extra", ENGINES)
def test_sharded_frames_bit_and_byte_equal(engine, extra, mode):
    """encode_sharded_message's shipped pieces merge bit-identical to the
    single-frame shipped leaf (leaf-aligned shards keep whole tensors, so
    per-segment quantization scales are unchanged), and each payload's
    size matches the static shard_frame_bytes_static accounting."""
    tree = _random_tree(7, 5)
    space = ParamSpace.from_tree(tree)
    cspec = CompressionSpec(engine=engine, **extra)
    msg, seg = _arena_message(space, 9, density=0.4, spec=cspec)
    _, ship_single = wire.encode_message(wire.UP, 1, 0, [msg],
                                         mode=mode, seg=seg)
    for S in (1, 2, 3, 5):
        spec = ShardSpec.for_space(space, S)
        frames = wire.encode_sharded_message(wire.UP, 1, 0, msg,
                                             shard_spec=spec, mode=mode,
                                             seg=seg)
        assert len(frames) == S
        static = wire.shard_frame_bytes_static(spec, seg, mode)
        shipped_pieces = []
        for (payload, shipped), nbytes, size in zip(frames, static,
                                                    spec.sizes):
            assert len(payload) == nbytes
            decoded = wire.decode_message(payload)
            assert int(decoded.leaves[0].size) == size
            np.testing.assert_array_equal(np.asarray(decoded.leaves[0].values),
                                          np.asarray(shipped[0].values))
            shipped_pieces.append(shipped[0])
        merged = spec.merge(shipped_pieces)
        np.testing.assert_array_equal(np.asarray(merged.values),
                                      np.asarray(ship_single[0].values))
        np.testing.assert_array_equal(np.asarray(merged.indices),
                                      np.asarray(ship_single[0].indices))


# ---------------------------------------------------------------------------
# in-graph route kernel (DESIGN.md §14): kernels.ops.route_by_shard vs a
# host reference built from the same ShardSpec ownership rule
# ---------------------------------------------------------------------------

def _route_case(seed: int, total: int, S: int, k: int, B: int = 1):
    """Random ragged bounds (empty shards legal), ~20% -1 padding, and
    INTEGER-valued float32 values so duplicate-index f32 scatter sums are
    exact regardless of the kernel's internal reordering."""
    rng = np.random.default_rng(seed)
    interior = np.sort(rng.integers(0, total + 1, size=S - 1))
    spec = ShardSpec(bounds=(0, *(int(b) for b in interior), total))
    idx = rng.integers(0, total, size=(B, k)).astype(np.int32)
    idx[rng.random((B, k)) < 0.2] = -1
    vals = rng.integers(-8, 9, size=(B, k)).astype(np.float32)
    return spec, idx, vals


def _route_scatter(spec, ri, rv, total: int) -> np.ndarray:
    """Scatter one message's (S, cap) route buckets back to the global
    arena through each shard's bounds offset."""
    dense = np.zeros(total, np.float32)
    for s in range(spec.n_shards):
        li, lv = np.asarray(ri[s]), np.asarray(rv[s])
        m = li >= 0
        if m.any():
            size = spec.sizes[s]
            assert li[m].min() >= 0 and li[m].max() < size
            np.add.at(dense, spec.bounds[s] + li[m], lv[m])
        # empty slots carry exactly zero, never residue
        np.testing.assert_array_equal(lv[~m], 0.0)
    return dense


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(1, 6), st.integers(1, 24),
       st.integers(0, 2 ** 31))
def test_property_route_kernel_scatter_roundtrip(total, S, k, seed):
    """route_by_shard with cap=k (never overflows) + per-shard scatter
    through the bounds offsets == the direct global scatter, bit-for-bit,
    for ragged bounds, empty shards, and -1 padding."""
    from repro.kernels import ops

    spec, idx, vals = _route_case(seed, total, S, k)
    ri, rv, ovf = ops.route_by_shard(
        jnp.asarray(idx[0]), jnp.asarray(vals[0]),
        bounds=spec.bounds, n_shards=S, cap=k)
    assert int(ovf) == 0
    ref = np.zeros(total, np.float32)
    m = idx[0] >= 0
    np.add.at(ref, idx[0][m], vals[0][m])
    np.testing.assert_array_equal(_route_scatter(spec, ri, rv, total), ref)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 40), st.integers(1, 5), st.integers(1, 16),
       st.integers(1, 5), st.integers(0, 2 ** 31))
def test_property_route_batch_equals_single_calls(total, S, k, B, seed):
    """The fused batch kernel (one flat scatter for N chunks) returns
    exactly the per-message single-call results, overflow summed."""
    from repro.kernels import ops

    spec, idx, vals = _route_case(seed, total, S, k, B=B)
    cap = max(1, k - 1)   # tight cap: exercise the overflow leg too
    riB, rvB, ovfB = ops.route_by_shard_batch(
        jnp.asarray(idx), jnp.asarray(vals),
        bounds=spec.bounds, n_shards=S, cap=cap)
    total_ovf = 0
    for b in range(B):
        ri1, rv1, ovf1 = ops.route_by_shard(
            jnp.asarray(idx[b]), jnp.asarray(vals[b]),
            bounds=spec.bounds, n_shards=S, cap=cap)
        np.testing.assert_array_equal(np.asarray(riB[b]), np.asarray(ri1))
        np.testing.assert_array_equal(np.asarray(rvB[b]), np.asarray(rv1))
        total_ovf += int(ovf1)
    assert int(ovfB) == total_ovf


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 40), st.integers(1, 5), st.integers(2, 20),
       st.integers(1, 4), st.integers(0, 2 ** 31))
def test_property_route_tight_cap_counts_and_keeps_prefix(total, S, k, cap,
                                                          seed):
    """With cap below a shard's bucket count the kernel reports EXACTLY
    sum_s max(0, count_s - cap) dropped entries, and the stable sort means
    each shard keeps its first `cap` entries in original message order."""
    from repro.kernels import ops

    spec, idx, vals = _route_case(seed, total, S, k)
    ri, rv, ovf = ops.route_by_shard(
        jnp.asarray(idx[0]), jnp.asarray(vals[0]),
        bounds=spec.bounds, n_shards=S, cap=cap)
    real = idx[0] >= 0
    owner = spec.owner_of(idx[0][real])
    counts = np.bincount(owner, minlength=S)
    assert int(ovf) == int(np.maximum(counts - cap, 0).sum())
    for s in range(S):
        kept = min(int(counts[s]), cap)
        mine = idx[0][real][owner == s][:kept] - spec.bounds[s]
        li = np.asarray(ri[s])
        np.testing.assert_array_equal(li[:kept], mine.astype(np.int32))
        assert (li[kept:] == -1).all()


def test_route_kernel_index_width_invariant():
    """int64 and int32 host indices produce identical buckets (jnp maps
    both onto the kernel's int32 index path)."""
    from repro.kernels import ops

    spec, idx, vals = _route_case(5, 100, 4, 16, B=3)
    out32 = ops.route_by_shard_batch(
        jnp.asarray(idx.astype(np.int32)), jnp.asarray(vals),
        bounds=spec.bounds, n_shards=4, cap=16)
    out64 = ops.route_by_shard_batch(
        jnp.asarray(idx.astype(np.int64)), jnp.asarray(vals),
        bounds=spec.bounds, n_shards=4, cap=16)
    for a, b in zip(out32, out64):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mesh_collective_equals_fallback_bitwise():
    """Pin shard_exchange_batch's two legs against each other: the
    all_to_all collective over 4 forced host devices must be bit-identical
    to the single-device swapaxes permutation (runs in a subprocess so the
    forced device count cannot leak into this process's jax runtime)."""
    import os
    import pathlib
    import subprocess
    import sys

    import repro

    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.paramspace import ParamSpace, ShardSpec
from repro.core import distributed
assert len(jax.devices()) >= 4, jax.devices()
params = {"a": jnp.zeros((300,)), "b": jnp.zeros((477,)),
          "c": jnp.zeros((223,))}
space = ParamSpace.from_tree(params)
spec = ShardSpec.for_space(space, 4)
rng = np.random.default_rng(0)
idx = rng.integers(0, space.total, size=(5, 37)).astype(np.int32)
idx[rng.random((5, 37)) < 0.2] = -1
vals = rng.integers(-8, 9, size=(5, 37)).astype(np.float32)
mesh = distributed.shard_exchange_batch(
    spec, jnp.asarray(idx), jnp.asarray(vals), use_mesh=True)
flat = distributed.shard_exchange_batch(
    spec, jnp.asarray(idx), jnp.asarray(vals), use_mesh=False)
for a, b in zip(mesh, flat):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("MESH_PARITY_OK")
"""
    root = pathlib.Path(next(iter(repro.__path__))).parent
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(root))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "MESH_PARITY_OK" in out.stdout


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 2 ** 31),
       st.sampled_from(MODES))
def test_property_quantized_split_is_verbatim(n_leaves, S, seed, mode):
    """Splitting AFTER quantization routes the quantized values verbatim:
    merge(split(quantize(msg))) == quantize(msg) bit-for-bit under every
    wire mode (leaf-aligned shards)."""
    tree = _random_tree(seed, n_leaves)
    space = ParamSpace.from_tree(tree)
    spec = ShardSpec.for_space(space, S)
    msg, seg = _arena_message(space, seed % 2 ** 16)
    shipped = wire.quantize_message(msg, mode, seg=seg)
    merged = spec.merge(
        [p for p, _ in spec.split_by_shard(shipped, seg)])
    np.testing.assert_array_equal(np.asarray(merged.values),
                                  np.asarray(shipped.values))
    np.testing.assert_array_equal(np.asarray(merged.indices),
                                  np.asarray(shipped.indices))
