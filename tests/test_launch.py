"""Launch-layer units: sharding rules, roofline extraction, shapes."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.core.distributed import rows_view, shardedps_state_size
from repro.launch import roofline
from repro.launch.sharding import param_specs, shard_axis_hints
from repro.models.model import abstract_params


class TestShardingRules:
    def test_dense_projections(self):
        cfg = get_arch("command-r-35b")
        shapes = abstract_params(cfg)
        specs = param_specs(cfg, shapes, 16)
        # stacked unit params get a leading None
        up = specs["units"]["b0"]["mlp"]["up"]["w"]
        assert tuple(up) == (None, None, "model")
        down = specs["units"]["b0"]["mlp"]["down"]["w"]
        assert tuple(down) == (None, "model", None)
        wq = specs["units"]["b0"]["attn"]["wq"]["w"]
        assert tuple(wq) == (None, None, "model")

    def test_kv_heads_guard(self):
        """kv < model_size -> K/V projections replicated (rope-safety)."""
        cfg = get_arch("chatglm3-6b")  # kv=2
        specs = param_specs(cfg, abstract_params(cfg), 16)
        wk = specs["units"]["b0"]["attn"]["wk"]["w"]
        assert tuple(wk) == (None, None, None)
        cfg2 = get_arch("musicgen-large")  # kv=32 >= 16
        specs2 = param_specs(cfg2, abstract_params(cfg2), 16)
        wk2 = specs2["units"]["b0"]["attn"]["wk"]["w"]
        assert tuple(wk2) == (None, None, "model")

    def test_moe_expert_parallel(self):
        cfg = get_arch("dbrx-132b")
        specs = param_specs(cfg, abstract_params(cfg), 16)
        up = specs["units"]["b0"]["moe"]["up"]
        assert tuple(up) == (None, "model", None, None)
        router = specs["units"]["b0"]["moe"]["router"]["w"]
        assert "model" not in tuple(router)

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_hints_match_specs(self, arch):
        cfg = get_arch(arch)
        shapes = abstract_params(cfg)
        hints = shard_axis_hints(cfg, shapes, 16)
        leaves = jax.tree.leaves(shapes)
        assert len(hints) == len(leaves)
        for h, l in zip(hints, leaves):
            if h is not None:
                assert 0 <= h < l.ndim
                assert l.shape[h] % 16 == 0


class TestRowsView:
    def test_flat(self):
        assert rows_view((100,), None) == (1, 100, None)

    def test_sharded_axis(self):
        S, rest, ax = rows_view((64, 128), 1)
        assert (S, rest, ax) == (128, 64, 1)

    def test_folding_large(self):
        # (94 units, 128 experts, 4096, 1536): shard axis 1, rest folded
        S, rest, ax = rows_view((94, 128, 4096, 1536), 1)
        assert S * rest == 94 * 128 * 4096 * 1536
        assert rest <= (1 << 22) or S == 128 * 94 * 4096
        assert shardedps_state_size((94, 128, 4096, 1536), 1, 16) >= \
            94 * 128 * 4096 * 1536 // 16


class TestRoofline:
    HLO = """
  %ag = bf16[32,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[512]{0} all-reduce(%y), to_apply=%sum
  %a2a = (f32[16,64]{1,0}, f32[16,64]{1,0}) all-to-all(%a, %b)
  %cp = bf16[8]{0} collective-permute(%z)
  %ags = bf16[4,4]{1,0} all-gather-start(%w)
"""

    def test_collective_stats(self):
        stats = roofline.collective_stats(self.HLO)
        assert stats["all-gather"]["count"] == 2
        assert stats["all-gather"]["out_bytes"] == 32 * 1024 * 2 + 16 * 2
        assert stats["all-reduce"]["count"] == 1
        assert stats["all-reduce"]["wire_bytes"] == 2.0 * 512 * 4
        assert stats["all-to-all"]["out_bytes"] == 2 * 16 * 64 * 4
        assert stats["collective-permute"]["count"] == 1

    def test_model_flops(self):
        from repro.configs import SHAPES
        cfg = get_arch("chatglm3-6b")
        f_train = roofline.model_flops(cfg, SHAPES["train_4k"])
        assert f_train == pytest.approx(
            6 * cfg.param_count() * 4096 * 256, rel=1e-6)
        f_dec = roofline.model_flops(cfg, SHAPES["decode_32k"])
        assert f_dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)

    def test_moe_active_params(self):
        from repro.configs import SHAPES
        cfg = get_arch("qwen3-moe-235b-a22b")
        f = roofline.model_flops(cfg, SHAPES["train_4k"])
        n_active_implied = f / (6 * 4096 * 256)
        # ~22B active for the 235B model
        assert 1.5e10 < n_active_implied < 3.5e10
