import numpy as np
import pytest

from repro.core import async_sim


def test_schedule_deterministic():
    a = async_sim.make_schedule(8, 100, seed=5, hetero=0.5)
    b = async_sim.make_schedule(8, 100, seed=5, hetero=0.5)
    np.testing.assert_array_equal(a, b)
    assert set(np.unique(a)) <= set(range(8))


def test_schedule_fair_when_homogeneous():
    s = async_sim.make_schedule(4, 4000, seed=0, hetero=0.0)
    counts = np.bincount(s, minlength=4)
    assert counts.min() > 0.8 * counts.max()


def test_schedule_stragglers_when_heterogeneous():
    s = async_sim.make_schedule(4, 4000, seed=0, hetero=1.5)
    counts = np.bincount(s, minlength=4)
    assert counts.max() > 2 * counts.min()  # fast workers dominate


def test_staleness_grows_with_workers():
    import jax, jax.numpy as jnp
    from repro.core import make_strategy

    def grad_fn(p, b):
        return jnp.sum(p["w"] ** 2), jax.tree.map(lambda x: 2 * x, p)

    def batch_fn(e, k):
        return None

    params0 = {"w": jnp.ones((4,))}
    stats = []
    for n in (2, 8):
        tr = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, n,
                                    lr=0.01)
        sched = async_sim.make_schedule(n, 120, seed=1, hetero=0.3)
        _, _, hist = tr.run(params0, sched, batch_fn)
        stats.append(hist.staleness[n * 2:].mean())
    assert stats[1] > stats[0]


# ---------------------------------------------------------------------------
# batched event loop: scheduling properties + bit-for-bit parity
# ---------------------------------------------------------------------------

def test_batch_schedule_partition_properties():
    for seed in range(5):
        sched = async_sim.make_schedule(7, 200, seed=seed, hetero=0.8)
        batches = async_sim.batch_schedule(sched)
        # exact partition: concatenating the batches recovers the schedule
        np.testing.assert_array_equal(np.concatenate(batches), sched)
        for b in batches:
            assert len(set(int(x) for x in b)) == len(b)  # distinct workers
            assert len(b) & (len(b) - 1) == 0             # power of two


def test_batch_schedule_max_batch_and_cut_every():
    sched = async_sim.make_schedule(9, 300, seed=2, hetero=0.3)
    for max_batch, cut_every in [(4, None), (None, 16), (8, 24)]:
        batches = async_sim.batch_schedule(sched, max_batch=max_batch,
                                           cut_every=cut_every)
        np.testing.assert_array_equal(np.concatenate(batches), sched)
        i = 0
        for b in batches:
            if max_batch is not None:
                assert len(b) <= max_batch
            if cut_every is not None:
                # a batch never straddles an eval boundary
                assert i // cut_every == (i + len(b) - 1) // cut_every
            i += len(b)


def _parity_problem():
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    params0 = {
        "w1": jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32)),
        "b1": jnp.zeros(16),
        "w2": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32)),
    }
    X = jnp.asarray(rng.normal(size=(64, 12)).astype(np.float32))
    Y = jnp.asarray(rng.integers(0, 4, 64))

    def grad_fn(p, batch):
        x, y = batch

        def loss(q):
            h = jnp.tanh(x @ q["w1"] + q["b1"]) @ q["w2"]
            lp = jax.nn.log_softmax(h)
            return -jnp.mean(lp[jnp.arange(x.shape[0]), y])

        return jax.value_and_grad(loss)(p)

    def batch_fn(e, k):
        i = (e * 7 + k * 3) % 56
        return (X[i:i + 8], Y[i:i + 8])

    return params0, grad_fn, batch_fn


def _assert_runs_equal(tr, params0, sched, batch_fn, **kw):
    import jax

    f1, s1, h1 = tr.run(params0, sched, batch_fn, **kw)
    f2, s2, h2 = tr.run_batched(params0, sched, batch_fn, **kw)
    np.testing.assert_array_equal(h1.losses, h2.losses)
    assert h1.up_bytes == h2.up_bytes
    assert h1.down_bytes == h2.down_bytes
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s1.M), np.asarray(s2.M))
    np.testing.assert_array_equal(np.asarray(s1.v), np.asarray(s2.v))
    return h1, h2


_PARITY_CONFIGS = [
    # (strategy, kwargs, secondary_density, down_quantize, engine)
    ("dgs", dict(density=0.1), 0.1, "int8", "exact"),
    ("dgs", dict(density=0.2), 0.15, "bf16", "sampled"),
    ("dgs", dict(density=0.1), 0.1, "tern", "blockwise"),
    ("dgc_async", dict(density=0.1), 0.1, "none", "exact"),
    ("asgd", dict(), None, "none", "exact"),
    ("gd_async", dict(density=0.1), 0.1, "int8", "exact"),
]


@pytest.mark.parametrize("name,kw,sec,dq,eng", _PARITY_CONFIGS)
def test_batched_matches_serial_bitwise(name, kw, sec, dq, eng):
    """The tentpole contract: run_batched == run, bit for bit — losses,
    byte accounting, final params, and server state — across strategies,
    compression engines, and wire quantize modes."""
    from repro.core import engine as engine_lib
    from repro.core import make_strategy

    specs = {
        "exact": engine_lib.CompressionSpec(engine="exact", quantize=dq),
        "sampled": engine_lib.CompressionSpec(engine="sampled", quantize=dq),
        "blockwise": engine_lib.CompressionSpec(engine="blockwise",
                                                quantize=dq, block_r=4),
    }
    params0, grad_fn, batch_fn = _parity_problem()
    sched = async_sim.make_schedule(5, 40, seed=3, hetero=0.8)
    tr = async_sim.AsyncTrainer(make_strategy(name, **kw), grad_fn, 5,
                                lr=0.05, secondary_density=sec,
                                secondary_spec=specs[eng])
    _assert_runs_equal(tr, params0, sched, batch_fn)


def test_batched_matches_serial_with_lr_fn_and_eval():
    from repro.core import make_strategy
    from repro.core.paramspace import ParamSpace

    params0, grad_fn, batch_fn = _parity_problem()
    sched = async_sim.make_schedule(5, 40, seed=1, hetero=0.5)
    tr = async_sim.AsyncTrainer(make_strategy("dgs", density=0.1), grad_fn,
                                5, lr=0.05, secondary_density=0.1)
    space = ParamSpace.from_tree(params0)

    def eval_fn(model):
        return float(np.asarray(space.pack(model)).sum())

    h1, h2 = _assert_runs_equal(tr, params0, sched, batch_fn,
                                lr_fn=lambda e: 0.05 / (1 + 0.01 * e),
                                eval_fn=eval_fn, eval_every=8)
    assert [e for e, _ in h1.evals] == [e for e, _ in h2.evals]
    assert [v for _, v in h1.evals] == [v for _, v in h2.evals]


def test_batched_max_batch_one_matches_serial():
    from repro.core import make_strategy

    params0, grad_fn, batch_fn = _parity_problem()
    sched = async_sim.make_schedule(4, 24, seed=6, hetero=0.5)
    tr = async_sim.AsyncTrainer(make_strategy("dgc_async", density=0.1),
                                grad_fn, 4, lr=0.05, secondary_density=0.1)
    f1, s1, h1 = tr.run(params0, sched, batch_fn)
    f2, s2, h2 = tr.run_batched(params0, sched, batch_fn, max_batch=1)
    np.testing.assert_array_equal(h1.losses, h2.losses)
    import jax
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# flight-recorder telemetry: metrics must not perturb the data plane
# ---------------------------------------------------------------------------

_METRICS_CONFIGS = [
    # (strategy, kwargs, secondary_density)
    ("dgs", dict(density=0.1, quantize="int8"), 0.1),
    ("dgc_async", dict(density=0.1), 0.1),
    ("asgd", dict(), None),
]


@pytest.mark.parametrize("name,kw,sec", _METRICS_CONFIGS)
def test_metrics_do_not_change_bits(name, kw, sec):
    """DESIGN.md §11's contract: metrics ON is bit-identical to metrics
    OFF — losses, final params, byte totals — in every runner (serial,
    batched, scan), and all three runners agree on the drained
    MetricsState itself."""
    import jax
    import jax.numpy as jnp

    from repro.core import make_strategy
    from repro.core.scan_runner import run_async_scan
    from repro.telemetry import metrics as metrics_lib

    params0, grad_fn, batch_fn = _parity_problem()
    n_workers, n_events = 5, 40
    sched = async_sim.make_schedule(n_workers, n_events, seed=3, hetero=0.8)
    tr = async_sim.AsyncTrainer(make_strategy(name, **kw), grad_fn,
                                n_workers, lr=0.05, secondary_density=sec)

    f_off, _, h_off = tr.run(params0, sched, batch_fn)
    f_on, _, h_on = tr.run(params0, sched, batch_fn, metrics=True)
    np.testing.assert_array_equal(h_off.losses, h_on.losses)
    assert (h_off.up_bytes, h_off.down_bytes) == (h_on.up_bytes,
                                                  h_on.down_bytes)
    for a, b in zip(jax.tree.leaves(f_off), jax.tree.leaves(f_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_off.metrics is None

    f_b, _, h_b = tr.run_batched(params0, sched, batch_fn, metrics=True)
    np.testing.assert_array_equal(h_off.losses, h_b.losses)
    for a, b in zip(jax.tree.leaves(f_off), jax.tree.leaves(f_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    strat = make_strategy(name, **kw)
    batches = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    f_sc_off, h_sc_off = run_async_scan(
        strat, grad_fn, params0, sched, stacked, n_workers=n_workers,
        lr=0.05, secondary_density=sec)
    f_sc, h_sc = run_async_scan(
        strat, grad_fn, params0, sched, stacked, n_workers=n_workers,
        lr=0.05, secondary_density=sec, metrics=True)
    np.testing.assert_array_equal(np.asarray(h_sc_off.losses),
                                  np.asarray(h_sc.losses))
    np.testing.assert_array_equal(h_off.losses, np.asarray(h_sc.losses))
    assert (h_sc_off.up_bytes, h_sc_off.down_bytes) == (h_sc.up_bytes,
                                                        h_sc.down_bytes)
    for a, b in zip(jax.tree.leaves(f_sc_off), jax.tree.leaves(f_sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the drained state: correct content, and runner-independent.  The
    # magnitude histogram's bucket is a float reduction (|G|^2), so it is
    # checked for mass only; every integer-exact histogram must agree
    # across runners bucket-for-bucket.
    md = h_on.metrics
    assert md["n_events"] == n_events
    assert md["per_worker"] == np.bincount(
        sched, minlength=n_workers).tolist()
    assert sum(md["staleness_hist"]["counts"]) == n_events
    assert sum(md["update_mag_hist"]["counts"]) == n_events
    assert md["staleness_hist"] == metrics_lib.summarize_log2(
        h_on.staleness)
    for other in (h_b.metrics, h_sc.metrics):
        a, b = dict(md), dict(other)
        a.pop("update_mag_hist"), b.pop("update_mag_hist")
        assert a == b
        assert sum(other["update_mag_hist"]["counts"]) == n_events
