import numpy as np

from repro.core import async_sim


def test_schedule_deterministic():
    a = async_sim.make_schedule(8, 100, seed=5, hetero=0.5)
    b = async_sim.make_schedule(8, 100, seed=5, hetero=0.5)
    np.testing.assert_array_equal(a, b)
    assert set(np.unique(a)) <= set(range(8))


def test_schedule_fair_when_homogeneous():
    s = async_sim.make_schedule(4, 4000, seed=0, hetero=0.0)
    counts = np.bincount(s, minlength=4)
    assert counts.min() > 0.8 * counts.max()


def test_schedule_stragglers_when_heterogeneous():
    s = async_sim.make_schedule(4, 4000, seed=0, hetero=1.5)
    counts = np.bincount(s, minlength=4)
    assert counts.max() > 2 * counts.min()  # fast workers dominate


def test_staleness_grows_with_workers():
    import jax, jax.numpy as jnp
    from repro.core import make_strategy

    def grad_fn(p, b):
        return jnp.sum(p["w"] ** 2), jax.tree.map(lambda x: 2 * x, p)

    def batch_fn(e, k):
        return None

    params0 = {"w": jnp.ones((4,))}
    stats = []
    for n in (2, 8):
        tr = async_sim.AsyncTrainer(make_strategy("asgd"), grad_fn, n,
                                    lr=0.01)
        sched = async_sim.make_schedule(n, 120, seed=1, hetero=0.3)
        _, _, hist = tr.run(params0, sched, batch_fn)
        stats.append(hist.staleness[n * 2:].mean())
    assert stats[1] > stats[0]
