"""REQUIRED per-architecture smoke tests: a reduced variant of each of the
10 assigned architectures runs one forward + one train step on CPU with
correct output shapes and no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, input_specs
from repro.models import decode_step, forward, init_caches, init_params, loss_fn
from repro.models.model import abstract_params


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), cfg.cdtype)
    logits, aux = forward(params, batch["tokens"], cfg,
                          frontend_embeds=batch.get("frontend_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one SGD train step
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = loss_fn(new_params, batch, cfg)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 64
    caches = init_caches(cfg, B, L)
    token = jnp.ones((B, 1), jnp.int32)
    logits, new_caches = decode_step(params, caches, token, jnp.int32(5),
                                     cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_abstract_params(arch):
    """Full configs instantiate abstractly (no allocation) with the right
    parameter count (within 1% of the analytic formula)."""
    cfg = get_arch(arch)
    shapes = abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    assert abs(total - analytic) / analytic < 0.01


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_exist(arch, shape):
    cfg, shp = get_arch(arch), SHAPES[shape]
    specs = input_specs(cfg, shp)
    if shp.kind in ("train", "prefill"):
        assert specs["tokens"].shape == (shp.global_batch, shp.seq_len)
    else:
        assert specs["token"].shape == (shp.global_batch, 1)
        assert len(jax.tree.leaves(specs["caches"])) > 0
