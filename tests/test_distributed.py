"""Mesh-distributed DGS exchange: numerical equivalence and end-to-end
training on a multi-device host mesh.

These tests need >1 device, so each runs in a subprocess with
--xla_force_host_platform_device_count set BEFORE jax import (the main
pytest process keeps the default single device, per the dry-run contract).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8) -> str:
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import sys
        sys.path.insert(0, {os.path.join(REPO, 'src')!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
    """) + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_mesh_allgather_matches_flat_reference():
    """The mesh allgather exchange (per-worker SAMomentum + sparse gather)
    must aggregate to the same update as a serial per-worker reference."""
    out = _run("""
        from repro.core import distributed as D
        from repro.core.samomentum import leaf_update
        from repro.launch import mesh as mesh_lib

        W = 8
        mesh = mesh_lib.make_mesh((W,), ("data",))
        n = 64
        key = jax.random.PRNGKey(0)
        grads_w = jax.random.normal(key, (W, n))     # per-worker grads
        u0 = jnp.zeros((W, n))
        cfg = D.ExchangeConfig(mode="allgather", density=0.25, momentum=0.5)

        def inner(u, g):
            u = u[0]
            upd, state = D.allgather_exchange(
                D.ExchangeState(velocity=[u], m_shard=[], v_shard=[]),
                [g[0]], cfg=cfg, lr=0.1, axis_names=("data",), n_workers=W)
            return upd[0], state.velocity[0][None]

        upd, u1 = jax.shard_map(
            inner, mesh=mesh, axis_names={"data"},
            in_specs=(P("data"), P("data")), out_specs=(P(), P("data")),
            check_vma=False)(u0, grads_w)
        # serial reference
        k = max(1, round(0.25 * n))
        agg = np.zeros(n)
        for w in range(W):
            msg, _ = leaf_update(jnp.zeros(n), grads_w[w], momentum=0.5,
                                 lr=0.1, k=k)
            np.add.at(agg, np.asarray(msg.indices), np.asarray(msg.values))
        np.testing.assert_allclose(np.asarray(upd), agg / W, atol=1e-5)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_mesh_allgather_auto_engine_dispatch_matches_exact():
    """engine="auto" with sampled_threshold_above=1 forces every leaf
    through the SAMPLED engine; the aggregated update must still match the
    exact engine (the sampled threshold only skips the sort, the selected
    support is identical) — behavioural proof the ExchangeConfig knob is
    respected on the mesh path."""
    out = _run("""
        from repro.core import distributed as D
        from repro.launch import mesh as mesh_lib

        W = 4
        mesh = mesh_lib.make_mesh((W,), ("data",))
        n = 256
        key = jax.random.PRNGKey(7)
        grads_w = jax.random.normal(key, (W, n))
        u0 = jnp.zeros((W, n))

        def run_with(cfg):
            def inner(u, g):
                upd, st = D.allgather_exchange(
                    D.ExchangeState(velocity=[u[0]], m_shard=[], v_shard=[]),
                    [g[0]], cfg=cfg, lr=0.1, axis_names=("data",),
                    n_workers=W)
                return upd[0], st.velocity[0][None]
            return jax.shard_map(
                inner, mesh=mesh, axis_names={"data"},
                in_specs=(P("data"), P("data")), out_specs=(P(), P("data")),
                check_vma=False)(u0, grads_w)

        upd_e, u_e = run_with(D.ExchangeConfig(
            mode="allgather", density=0.1, momentum=0.5, engine="exact"))
        # auto below the cutoff == exact, bit for bit
        upd_a, u_a = run_with(D.ExchangeConfig(
            mode="allgather", density=0.1, momentum=0.5, engine="auto",
            sampled_threshold_above=1 << 30))
        np.testing.assert_array_equal(np.asarray(upd_a), np.asarray(upd_e))
        np.testing.assert_array_equal(np.asarray(u_a), np.asarray(u_e))
        # auto above the cutoff routes through the (approximate, sort-free)
        # sampled engine: still <= W*k shipped slots and most of the exact
        # update's mass recovered
        upd_s, u_s = run_with(D.ExchangeConfig(
            mode="allgather", density=0.1, momentum=0.5, engine="auto",
            sampled_threshold_above=1))
        upd_s = np.asarray(upd_s)
        k = max(1, round(0.1 * n))
        assert np.count_nonzero(upd_s) <= W * k
        mass_s = np.abs(upd_s).sum()
        mass_e = np.abs(np.asarray(upd_e)).sum()
        assert mass_s > 0.5 * mass_e, (mass_s, mass_e)
        assert np.all(np.isfinite(np.asarray(u_s)))
        print("AUTO_DISPATCH_MATCH")
    """, devices=4)
    assert "AUTO_DISPATCH_MATCH" in out


def _supports_partial_auto() -> bool:
    from repro.compat import supports_partial_auto_shard_map
    return supports_partial_auto_shard_map()


@pytest.mark.skipif(
    not _supports_partial_auto(),
    reason="partial-auto shard_map (manual data + auto model axis of size "
           ">1) crashes the XLA SPMD partitioner on jax 0.4.x; "
           "model_par=1 paths are covered by the other mesh tests")
def test_mesh_train_step_loss_decreases():
    """End-to-end: reduced arch trains on a (4 data x 2 model) mesh with the
    sparse exchange and the loss goes down."""
    out = _run("""
        from repro.configs import get_arch
        from repro.configs.shapes import InputShape, input_specs
        from repro.core.distributed import ExchangeConfig
        from repro.data.synthetic import TokenStream
        from repro.launch import mesh as mesh_lib
        from repro.launch.steps import build_train_step, init_exchange_state
        from repro.models import init_params

        cfg = get_arch("chatglm3-6b").reduced()
        mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
        shape = InputShape("smoke", 64, 8, "train")
        ex_cfg = ExchangeConfig(mode="allgather", density=0.1, momentum=0.9)
        bundle = build_train_step(cfg, mesh, ex_cfg, lr=0.2,
                                  batch_specs_abstract=input_specs(cfg, shape),
                                  remat=False)
        params = init_params(jax.random.PRNGKey(0), cfg)
        ex_state = init_exchange_state(params, ex_cfg, 4)
        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64,
                             batch_size=8, seed=0)
        with mesh:
            step = bundle.jit()
            losses = []
            for i in range(30):
                params, ex_state, loss = step(params, ex_state,
                                              stream.batch(i))
                losses.append(float(loss))
        assert all(np.isfinite(losses)), losses
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
        print("DECREASED", losses[0], losses[-1])
    """)
    assert "DECREASED" in out


def test_mesh_dense_mode_matches_single_device_msgd():
    """dense exchange on a 4-worker mesh == single-device momentum SGD on
    the concatenated batch (the classic DP equivalence)."""
    out = _run("""
        from repro.configs import get_arch
        from repro.configs.shapes import InputShape, input_specs
        from repro.core.distributed import ExchangeConfig
        from repro.launch import mesh as mesh_lib
        from repro.launch.steps import build_train_step, init_exchange_state
        from repro.models import init_params, loss_fn
        from repro.core.baselines import msgd_step

        cfg = get_arch("musicgen-large").reduced()
        cfg = __import__("dataclasses").replace(cfg, frontend_tokens=0)
        mesh = mesh_lib.make_mesh((4, 1), ("data", "model"))
        shape = InputShape("smoke", 32, 8, "train")
        ex_cfg = ExchangeConfig(mode="dense", momentum=0.7)
        bundle = build_train_step(cfg, mesh, ex_cfg, lr=0.1,
                                  batch_specs_abstract=input_specs(cfg, shape),
                                  remat=False)
        params = init_params(jax.random.PRNGKey(0), cfg)
        # params is donated into step(); keep an independent copy for the
        # serial reference
        ref_params = jax.tree.map(jnp.copy, params)
        ref_vel = jax.tree.map(jnp.zeros_like, params)
        ex_state = init_exchange_state(params, ex_cfg, 4)
        key = jax.random.PRNGKey(1)
        with mesh:
            step = bundle.jit()
            for i in range(3):
                tokens = jax.random.randint(jax.random.fold_in(key, i),
                                            (8, 32), 0, cfg.vocab_size)
                batch = {"tokens": tokens}
                params, ex_state, loss = step(params, ex_state, batch)
                # reference: grad over the same full batch
                g = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(ref_params)
                ref_params, ref_vel = msgd_step(ref_params, ref_vel, g,
                                                lr=0.1, momentum=0.7)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(ref_params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-3)
        print("EQUIV")
    """)
    assert "EQUIV" in out


def test_multipod_mesh_axes():
    out = _run("""
        from repro.launch import mesh as mesh_lib
        m = mesh_lib.make_production_mesh(multi_pod=True)
        assert dict(m.shape) == {"pod": 2, "data": 16, "model": 16}
        assert mesh_lib.data_axis_names(m) == ("pod", "data")
        assert mesh_lib.n_data_workers(m) == 32
        assert mesh_lib.model_axis_size(m) == 16
        m1 = mesh_lib.make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        print("AXES_OK")
    """, devices=512)
    assert "AXES_OK" in out


def test_shardedps_equals_allgather_when_unconstrained():
    """With generous bucket capacity and a dense downward pass, the
    sharded-PS dual-way exchange delivers exactly the same aggregated update
    as the allgather exchange (nothing left in the M - v difference)."""
    out = _run("""
        from repro.core import distributed as D
        from repro.launch import mesh as mesh_lib

        W = 8
        mesh = mesh_lib.make_mesh((W,), ("data",))
        n = 64
        key = jax.random.PRNGKey(0)
        grads_w = jax.random.normal(key, (W, n))
        u0 = jnp.zeros((W, n))
        cfg_ag = D.ExchangeConfig(mode="allgather", density=0.25,
                                  momentum=0.5)
        cfg_sp = D.ExchangeConfig(mode="shardedps", density=0.25,
                                  momentum=0.5, bucket_factor=float(W),
                                  secondary_density=1.0)
        shard = n // W

        def inner_ag(u, g):
            upd, st = D.allgather_exchange(
                D.ExchangeState(velocity=[u[0]], m_shard=[], v_shard=[]),
                [g[0]], cfg=cfg_ag, lr=0.1, axis_names=("data",),
                n_workers=W)
            return upd[0], st.velocity[0][None]

        def inner_sp(u, g, m, v):
            upd, st = D.shardedps_exchange(
                D.ExchangeState(velocity=[u[0]], m_shard=[m[0]],
                                v_shard=[v[0]]),
                [g[0]], cfg=cfg_sp, lr=0.1, axis_names=("data",),
                n_workers=W)
            return (upd[0], st.velocity[0][None], st.m_shard[0][None],
                    st.v_shard[0][None])

        upd_ag, u_ag = jax.shard_map(
            inner_ag, mesh=mesh, axis_names={"data"},
            in_specs=(P("data"), P("data")), out_specs=(P(), P("data")),
            check_vma=False)(u0, grads_w)
        m0 = jnp.zeros((W, shard))
        upd_sp, u_sp, m1, v1 = jax.shard_map(
            inner_sp, mesh=mesh, axis_names={"data"},
            in_specs=(P("data"), P("data"), P("data"), P("data")),
            out_specs=(P(), P("data"), P("data"), P("data")),
            check_vma=False)(u0, grads_w, m0, m0)
        np.testing.assert_allclose(np.asarray(upd_sp), np.asarray(upd_ag),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(u_sp), np.asarray(u_ag),
                                   atol=1e-5)
        # difference fully broadcast: M == v on every shard
        np.testing.assert_allclose(np.asarray(m1), np.asarray(v1), atol=1e-6)
        print("SPMATCH")
    """)
    assert "SPMATCH" in out


@pytest.mark.parametrize("arch", [
    "chatglm3-6b", "gemma3-12b", "zamba2-2.7b", "qwen2-vl-7b", "dbrx-132b",
    "musicgen-large", "mamba2-780m", "command-r-35b", "minicpm3-4b",
    "qwen3-moe-235b-a22b",
])
def test_mesh_serve_step_all_archs(arch):
    """Every reduced architecture's serve_step runs on a (2 data x 2 model)
    host mesh through the production step builder (shardings included)."""
    out = _run(f"""
        import dataclasses
        from repro.configs import get_arch
        from repro.configs.shapes import InputShape, concrete_inputs
        from repro.launch import mesh as mesh_lib
        from repro.launch.steps import build_serve_step
        from repro.models import init_params

        cfg = get_arch({arch!r}).reduced()
        mesh = mesh_lib.make_mesh((2, 2), ("data", "model"))
        shape = InputShape("smoke", 64, 4, "decode")
        bundle = build_serve_step(cfg, mesh, shape=shape)
        params = init_params(jax.random.PRNGKey(0), cfg)
        inputs = concrete_inputs(cfg, shape)
        with mesh:
            step = bundle.jit()
            logits, caches = step(params, inputs["caches"],
                                  inputs["token"], inputs["pos"])
        assert logits.shape == (4, 1, cfg.vocab_size), logits.shape
        assert not bool(jnp.any(jnp.isnan(logits)))
        print("SERVE_OK", logits.shape)
    """, devices=4)
    assert "SERVE_OK" in out
