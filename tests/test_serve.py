"""Serve leg: live replicas fed by sparse diffs (DESIGN.md §13).

The contracts under test:

* training parity — attaching a replica fleet changes NOTHING about the
  training run: losses, final params, and up/down byte accounting stay
  bit-identical to the simulator (serving reads M, never writes it, and
  push bytes live in their own counter family);
* bit-exact quiesce — every replica's final model equals the server's
  ``global_model`` bit for bit, for top-k pushes, exact-residual pushes,
  and quantized pushes alike (the dense SYNC handshake, not the sparse
  push history, carries the guarantee);
* delta-checkpoints — the coordinator's checkpoint chain restores to the
  live arena bit for bit;
* telemetry — per-replica ``sub/{i}/*`` lag/push counters are recorded;
* the TCP transport path end to end.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_sim, make_strategy
from repro.core.engine import CompressionSpec
from repro.core.paramspace import ParamSpace
from repro.cluster import run_inprocess
from repro.cluster.client import ClusterClient
from repro.cluster.coordinator import Coordinator
from repro.cluster.replica import InferenceReplica
from repro.cluster.scenarios import ClientPlan
from repro.cluster.transport import (TcpClientTransport,
                                     TcpCoordinatorTransport)


def _problem():
    key = jax.random.PRNGKey(0)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

        return jax.value_and_grad(loss)(params)

    def batch_fn(e, k):
        kk = jax.random.PRNGKey(int(e) * 131 + int(k) + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    return grad_fn, batch_fn, params0


def _reference(grad_fn, batch_fn, params0, sched, strat):
    tr = async_sim.AsyncTrainer(strat, grad_fn, 3, lr=0.03,
                                secondary_density=0.1)
    return tr.run(params0, sched, batch_fn)


@pytest.mark.parametrize("push_density,push_spec", [
    (0.3, CompressionSpec(engine="exact")),
    (None, CompressionSpec(engine="exact")),          # exact residual
    (0.3, CompressionSpec(engine="exact", quantize="int8")),
])
def test_replicas_bit_exact_and_training_untouched(push_density, push_spec):
    """Fleet attached -> replica finals == server model bitwise, and the
    training run is bit-identical to the no-fleet simulator reference."""
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(3, 30, seed=7, hetero=0.9)
    strat = make_strategy("dgs", density=0.2, momentum=0.7)
    f_sim, _, h_sim = _reference(grad_fn, batch_fn, params0, sched, strat)

    f, h = run_inprocess(strat, grad_fn, params0, batch_fn,
                         schedule=sched, lr=0.03, secondary_density=0.1,
                         n_replicas=2, push_density=push_density,
                         push_spec=push_spec, max_staleness=2, timeout=60)

    np.testing.assert_array_equal(h_sim.losses, h.losses)
    assert h_sim.up_bytes == h.up_bytes
    assert h_sim.down_bytes == h.down_bytes
    for a, b in zip(jax.tree.leaves(f_sim), jax.tree.leaves(f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    final_arena = np.asarray(ParamSpace.from_tree(params0).pack(f))
    replicas = h.metrics["replicas"]
    assert len(replicas) == 2
    for r in replicas:
        assert r is not None
        np.testing.assert_array_equal(r["arena"], final_arena)
        assert r["version"] == len(h.losses)
        assert r["diffs"] >= 1 and r["bytes_in"] > 0


def test_replica_lag_counters_recorded():
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(2, 20, seed=3)
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    _, h = run_inprocess(strat, grad_fn, params0, batch_fn,
                         schedule=sched, lr=0.03, secondary_density=0.1,
                         n_replicas=2, push_density=0.25, timeout=60)
    cnt = h.metrics["counters"]
    for i in range(2):
        assert cnt.get(f"sub/{i}/pushes", 0) >= 1
        assert cnt.get(f"sub/{i}/push_bytes", 0) > 0
        assert f"sub/{i}/lag_max" in cnt
        assert cnt.get(f"sub/{i}/version") == len(h.losses)
    # push traffic must NOT leak into the training byte accounting
    assert cnt.get("sub_joins") == 2 and cnt.get("sub_syncs") == 2


def test_replica_decode_fn_sees_fresh_models():
    """decode_fn runs at every boundary and the models it sees advance
    with the training run (version monotonicity through the diffs)."""
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(2, 24, seed=5)
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    seen = []

    def decode_fn(params, step):
        seen.append(float(jnp.sum(jnp.abs(params["w"]))))

    _, h = run_inprocess(strat, grad_fn, params0, batch_fn,
                         schedule=sched, lr=0.03, secondary_density=0.1,
                         n_replicas=1, push_density=0.25,
                         replica_decode_fn=decode_fn, timeout=60)
    r = h.metrics["replicas"][0]
    assert r["decodes"] == len(seen) >= 1
    # params0 is zeros: any applied diff moves |w| off zero
    assert seen[-1] > 0 or r["diffs"] <= 1


def test_runner_delta_checkpoint_matches_final(tmp_path):
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(2, 16, seed=9)
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    f, h = run_inprocess(strat, grad_fn, params0, batch_fn,
                         schedule=sched, lr=0.03, secondary_density=0.1,
                         ckpt_dir=tmp_path / "ckpt", ckpt_every=5,
                         timeout=60)
    from repro.checkpoint import load_delta_checkpoint
    arena, version, _ = load_delta_checkpoint(tmp_path / "ckpt")
    np.testing.assert_array_equal(
        arena, np.asarray(ParamSpace.from_tree(params0).pack(f)))
    assert version == len(h.losses)
    assert h.metrics["counters"].get("ckpt_deltas", 0) >= 2


def test_sharded_serving_rejected():
    grad_fn, batch_fn, params0 = _problem()
    sched = async_sim.make_schedule(2, 8, seed=1)
    strat = make_strategy("dgs", density=0.25, momentum=0.7)
    with pytest.raises(NotImplementedError):
        run_inprocess(strat, grad_fn, params0, batch_fn, schedule=sched,
                      n_shards=2, n_replicas=1)


def test_tcp_replica_bit_exact():
    """Real sockets: 2 training clients + 1 replica process-alike thread;
    the replica's final arena equals the server model bitwise."""
    grad_fn, batch_fn, params0 = _problem()
    strat = make_strategy("dgs", density=0.2, momentum=0.7)
    ct = TcpCoordinatorTransport()
    coord = Coordinator(transport=ct, params0=params0, n_slots=2,
                        secondary_density=0.2, recv_timeout=120.0,
                        push_density=0.3, min_subscribers=1)

    def client_main(cid):
        t = TcpClientTransport("127.0.0.1", ct.port, cid)
        ClusterClient(
            transport=t, strategy=strat, grad_fn=grad_fn, params0=params0,
            batch_fn=batch_fn, plan=ClientPlan(client_id=cid, n_rounds=6),
            lr=0.05).run()
        t.close()

    results = {}

    def replica_main():
        from repro.cluster import wire
        t = TcpClientTransport("127.0.0.1", ct.port,
                               wire.SUBSCRIBER_BASE + 0)
        results["replica"] = InferenceReplica(
            t, params0, replica_id=0, max_staleness=2,
            recv_timeout=120.0).run()
        t.close()

    threads = [threading.Thread(target=client_main, args=(i,), daemon=True)
               for i in range(2)]
    threads.append(threading.Thread(target=replica_main, daemon=True))
    for t in threads:
        t.start()
    final, hist = coord.serve()
    for t in threads:
        t.join(timeout=60)
    ct.close()

    assert len(hist.losses) == 12
    r = results["replica"]
    np.testing.assert_array_equal(
        r.arena, np.asarray(ParamSpace.from_tree(params0).pack(final)))
    assert r.version == 12
    cnt = hist.metrics["counters"]
    assert cnt.get("sub/0/pushes", 0) >= 1
