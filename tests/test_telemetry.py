"""Unit tests for the flight-recorder layer itself (DESIGN.md §11):
in-graph metrics, the trace/JSONL recorder, the log facility, and the
report renderer — the runner-integration contracts live in
test_async_sim.py / test_cluster.py."""
import json
import subprocess
import sys
import pathlib

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.telemetry import metrics as M

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# metrics: bucketing, update, drain
# ---------------------------------------------------------------------------

def test_log2_bin_buckets_split_at_powers_of_two():
    xs = jnp.asarray([0, 1, 2, 3, 6, 7, 14, 2 ** 30], jnp.int32)
    got = np.asarray(M.log2_bin(xs, M.N_BINS))
    # bucket b holds x in [2^b - 1, 2^(b+1) - 2]; huge values clip
    assert got.tolist() == [0, 1, 1, 2, 2, 3, 3, M.N_BINS - 1]


def test_update_batched_equals_sequential_scalars():
    ms_seq = M.init(4)
    wids = [0, 2, 2, 3]
    stals = [0, 3, 1, 7]
    nnzs = [5, 5, 9, 1]
    mags = [0.0, 2.5, 0.1, 40.0]
    for w, s, n, g in zip(wids, stals, nnzs, mags):
        ms_seq = M.update(ms_seq, jnp.int32(w), jnp.int32(s), jnp.int32(n),
                          jnp.int32(n), jnp.float32(g))
    ms_bat = M.update(M.init(4), jnp.asarray(wids, jnp.int32),
                      jnp.asarray(stals, jnp.int32),
                      jnp.asarray(nnzs, jnp.int32),
                      jnp.asarray(nnzs, jnp.int32),
                      jnp.asarray(mags, jnp.float32))
    assert M.drain(ms_seq) == M.drain(ms_bat)
    d = M.drain(ms_seq)
    assert d["n_events"] == 4
    assert d["per_worker"] == [1, 0, 2, 1]
    assert sum(d["staleness_hist"]["counts"]) == 4
    # the exact-zero magnitude landed in the reserved bin 0
    assert d["update_mag_hist"]["counts"][0] == 1


def test_route_overflow_accumulates_and_drains():
    """The overflow counter sums scalar and batched contributions across
    updates and drains as a plain int — zero when never fed."""
    ms = M.init(2)
    assert M.drain(ms)["route_overflow"] == 0
    ms = M.update(ms, jnp.int32(0), jnp.int32(0), jnp.int32(1),
                  jnp.int32(1), jnp.float32(1.0), overflow=jnp.int32(3))
    ms = M.update(ms, jnp.asarray([0, 1], jnp.int32),
                  jnp.asarray([0, 0], jnp.int32),
                  jnp.asarray([1, 1], jnp.int32),
                  jnp.asarray([1, 1], jnp.int32),
                  jnp.asarray([1.0, 1.0], jnp.float32),
                  overflow=jnp.asarray([2, 5], jnp.int32))
    assert M.drain(ms)["route_overflow"] == 10


def test_summarize_log2_is_the_host_twin():
    vals = [0, 1, 5, 100, 1000, 1000, 2 ** 20]
    ms = M.init(1)
    for v in vals:
        ms = M.update(ms, jnp.int32(0), jnp.int32(v), jnp.int32(0),
                      jnp.int32(0), jnp.float32(1.0))
    assert M.drain(ms)["staleness_hist"] == M.summarize_log2(vals)


def test_hist_dict_trims_trailing_zeros():
    h = M.hist_dict([0, 3, 0, 1, 0, 0])
    assert h["counts"] == [0, 3, 0, 1]
    assert len(h["bins"]) == 4


# ---------------------------------------------------------------------------
# trace: recorder artifacts
# ---------------------------------------------------------------------------

def test_recorder_writes_parseable_artifacts(tmp_path):
    with telemetry.Recorder(tmp_path) as rec:
        with rec.span("phase/a", detail=1):
            pass
        rec.instant("marker")
        rec.event("progress", event=1, loss=0.5)
        rec.count("client/0/retries")
        rec.count("client/0/retries")
    trace = json.loads((tmp_path / "trace.json").read_text())
    names = [e["name"] for e in trace["traceEvents"]]
    assert "phase/a" in names and "marker" in names
    span = next(e for e in trace["traceEvents"] if e["name"] == "phase/a")
    assert span["ph"] == "X" and span["dur"] >= 0
    lines = [json.loads(line) for line in
             (tmp_path / "events.jsonl").read_text().splitlines()]
    kinds = [e["kind"] for e in lines]
    assert kinds == ["progress", "counters"]
    assert lines[-1]["counters"] == {"client/0/retries": 2}


def test_null_recorder_is_free_and_writes_nothing():
    rec = telemetry.NULL
    assert not rec.enabled
    with rec.span("x"):
        pass
    rec.event("y", z=1)
    rec.count("c")
    assert rec.flush() == []
    assert rec.counters == {}


# ---------------------------------------------------------------------------
# logs: bare-message stdout + recorder mirroring
# ---------------------------------------------------------------------------

def test_logger_prints_bare_messages_and_mirrors_to_recorder(capsys):
    log = telemetry.get_logger("test")
    rec = telemetry.Recorder()
    telemetry.set_recorder(rec)
    try:
        log.info("[test] hello %d", 7)
    finally:
        telemetry.set_recorder(None)
    assert capsys.readouterr().out == "[test] hello 7\n"
    mirrored = [json.loads(line) for line in rec._jsonl]
    assert mirrored and mirrored[0]["kind"] == "log"
    assert mirrored[0]["msg"] == "[test] hello 7"
    assert mirrored[0]["logger"] == "test"


def test_log_level_silences(capsys):
    log = telemetry.get_logger("test")
    telemetry.set_level("warning")
    try:
        log.info("[test] chatter")
        log.warning("[test] kept")
    finally:
        telemetry.set_level("info")
    assert capsys.readouterr().out == "[test] kept\n"


# ---------------------------------------------------------------------------
# report: render + --check gate
# ---------------------------------------------------------------------------

def _fake_run_dir(tmp_path):
    rec = telemetry.Recorder(tmp_path)
    with rec.span("coord/server_batch"):
        pass
    rec.event("progress", event=1, loss=1.0, up_bytes=100, down_bytes=80)
    rec.event("progress", event=2, loss=0.5, up_bytes=200, down_bytes=160)
    rec.event("run_summary", runner="cluster", n_events=2, up_bytes=200,
              down_bytes=160, loss_first=1.0, loss_last=0.5,
              staleness_hist=M.summarize_log2([0, 1]),
              up_bytes_hist=M.summarize_log2([100, 100]),
              down_bytes_hist=M.summarize_log2([80, 80]))
    rec.count("client/0/events", 2)
    rec.flush()
    return tmp_path


def _report(*args):
    return subprocess.run(
        [sys.executable, "scripts/report.py", *map(str, args)],
        cwd=REPO_ROOT, capture_output=True, text=True)


def test_report_renders_and_check_passes(tmp_path):
    run_dir = _fake_run_dir(tmp_path)
    proc = _report(run_dir, "--check")
    assert proc.returncode == 0, proc.stderr
    assert "Staleness distribution" in proc.stdout
    assert "Per-stage time breakdown" in proc.stdout
    assert "coord/server_batch" in proc.stdout
    assert "Per-client activity" in proc.stdout
    assert "report --check: OK" in proc.stdout


def test_report_check_fails_on_missing_or_corrupt(tmp_path):
    assert _report(tmp_path / "nope", "--check").returncode == 1
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "trace.json").write_text("{}")
    (bad / "events.jsonl").write_text("not json\n")
    assert _report(bad, "--check").returncode == 1
