"""Delta-checkpoint chain: bit-exact restore under truncation/compaction.

The property (checkpoint/delta.py): a base arena plus a chain of
SET-semantics wire-framed deltas restores BIT-IDENTICALLY to every
recorded state, at every truncation point, before and after compaction —
for arena histories produced by the real update machinery (per-tensor
top-k through each selection engine, shipped through each wire
quantization mode), not just random perturbations.
"""
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, strategies
from repro.checkpoint import (DeltaCheckpointWriter, compact,
                              load_delta_checkpoint, read_manifest)

ENGINES = ("exact", "sampled", "blockwise")
MODES = ("none", "bf16", "int8", "tern")


def _arena_history(seed: int, n_deltas: int, engine: str, mode: str):
    """A realistic live-arena history: theta_0 plus n sparse committed
    updates, each selected per-tensor by ``engine`` and round-tripped
    through the wire codec in ``mode`` — the exact shape of states the
    coordinator's delta-checkpoint hook records."""
    import jax
    import jax.numpy as jnp

    from repro.cluster import wire
    from repro.core import server as ps
    from repro.core.engine import CompressionSpec
    from repro.core.paramspace import ParamSpace

    rng = np.random.default_rng(seed)
    params0 = {"w": rng.normal(size=(7, 5)).astype(np.float32),
               "b": rng.normal(size=(5,)).astype(np.float32)}
    space = ParamSpace.from_tree(params0)
    spec = CompressionSpec(engine=engine, quantize="none", block_r=2)
    ks = space.ks(0.3)
    arena = np.asarray(space.pack(params0))
    states = [arena.copy()]
    theta = jnp.asarray(arena)
    for t in range(n_deltas):
        g = jnp.asarray(rng.normal(size=arena.shape).astype(np.float32)
                        * rng.integers(0, 2, size=arena.shape))
        leaf = space.select(g, ks, spec)
        payload, _ = wire.encode_message(wire.DIFF, 0, t, [leaf],
                                         mode=mode, seg=ks)
        shipped = wire.decode_message(payload).leaves[0]
        theta = ps.apply_update(theta, shipped)
        states.append(np.asarray(theta))
    return states


def _write_chain(tmp_path, states):
    with DeltaCheckpointWriter(tmp_path, states[0], version=0,
                               meta={"test": True}) as w:
        for v, arena in enumerate(states[1:], start=1):
            w.append(arena, v)


@settings(max_examples=20, deadline=None) if HAVE_HYPOTHESIS else \
    (lambda f: f)
@given(strategies.integers(0, 2 ** 31 - 1),
       strategies.integers(1, 6),
       strategies.sampled_from(ENGINES),
       strategies.sampled_from(MODES))
def test_restore_bit_exact_at_every_truncation(seed, n_deltas, engine,
                                               mode):
    import tempfile
    states = _arena_history(seed, n_deltas, engine, mode)
    with tempfile.TemporaryDirectory() as d:
        _write_chain(d, states)
        for upto in range(len(states)):
            arena, version, meta = load_delta_checkpoint(d, upto=upto)
            assert version == upto
            assert meta == {"test": True}
            np.testing.assert_array_equal(arena, states[upto])
        # version-addressed truncation agrees with index truncation
        arena, version, _ = load_delta_checkpoint(
            d, upto_version=n_deltas // 2)
        np.testing.assert_array_equal(arena, states[n_deltas // 2])


@settings(max_examples=20, deadline=None) if HAVE_HYPOTHESIS else \
    (lambda f: f)
@given(strategies.integers(0, 2 ** 31 - 1),
       strategies.integers(2, 6),
       strategies.sampled_from(ENGINES),
       strategies.sampled_from(MODES))
def test_compaction_preserves_every_later_restore(seed, n_deltas, engine,
                                                  mode):
    import tempfile
    states = _arena_history(seed, n_deltas, engine, mode)
    with tempfile.TemporaryDirectory() as d:
        _write_chain(d, states)
        cut = n_deltas // 2
        compact(d, upto=cut)
        manifest = read_manifest(d)
        assert manifest["base_version"] == cut
        assert len(manifest["deltas"]) == n_deltas - cut
        # every restore point at/past the fold is bit-identical
        for v in range(cut, n_deltas + 1):
            arena, version, _ = load_delta_checkpoint(d, upto_version=v)
            assert version == v
            np.testing.assert_array_equal(arena, states[v])


def test_signed_zero_flip_is_recorded():
    """-0.0 -> +0.0 compares IEEE-equal but is a different bit pattern;
    the != changed-set predicate deliberately misses it, matching the
    repo-wide np.array_equal restore contract (which treats them equal)."""
    import tempfile
    base = np.asarray([1.0, -0.0, 2.0], np.float32)
    nxt = np.asarray([1.0, 0.0, 3.0], np.float32)
    with tempfile.TemporaryDirectory() as d:
        with DeltaCheckpointWriter(d, base) as w:
            w.append(nxt, 1)
        arena, _, _ = load_delta_checkpoint(d)
        assert np.array_equal(arena, nxt)


def test_empty_and_dense_deltas():
    """A no-change append is a valid (header-only) delta; a whole-arena
    rewrite auto-frames dense and restores as a full assignment."""
    import tempfile
    rng = np.random.default_rng(0)
    base = rng.normal(size=64).astype(np.float32)
    same = base.copy()
    dense = rng.normal(size=64).astype(np.float32)   # every entry changes
    with tempfile.TemporaryDirectory() as d:
        with DeltaCheckpointWriter(d, base) as w:
            e1 = w.append(same, 1)
            e2 = w.append(dense, 2)
        assert e1["k"] == 0
        assert e2["k"] == 64
        arena, version, _ = load_delta_checkpoint(d, upto=1)
        np.testing.assert_array_equal(arena, base)
        arena, version, _ = load_delta_checkpoint(d)
        np.testing.assert_array_equal(arena, dense)
        assert version == 2


def test_torn_tail_is_ignored():
    """The manifest is the commit point: bytes appended to deltas.bin
    without a manifest entry (a torn write) do not corrupt restore."""
    import pathlib
    import tempfile
    rng = np.random.default_rng(1)
    states = [rng.normal(size=16).astype(np.float32) for _ in range(3)]
    with tempfile.TemporaryDirectory() as d:
        with DeltaCheckpointWriter(d, states[0]) as w:
            w.append(states[1], 1)
            w.append(states[2], 2)
        with open(pathlib.Path(d) / "deltas.bin", "ab") as f:
            f.write(b"\x00garbage-torn-append")
        arena, version, _ = load_delta_checkpoint(d)
        np.testing.assert_array_equal(arena, states[2])
        assert version == 2


def test_size_mismatch_rejected():
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        with DeltaCheckpointWriter(d, np.zeros(8, np.float32)) as w:
            with pytest.raises(ValueError):
                w.append(np.zeros(9, np.float32), 1)
