"""Pallas kernel validation: interpret=True vs the pure-jnp ref.py oracles,
swept over shapes and dtypes (as required per kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(100,), (4096,), (333, 7), (8, 1024), (2, 3, 1000)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_samomentum_fused_sweep(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2 ** 31)
    u = jax.random.normal(key, shape).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
    thr = jnp.float32(0.5)
    out, unew = ops.samomentum_fused(u, g, thr, momentum=0.7, lr=0.1)
    r_out, r_unew, _ = ref.samomentum_ref(u, g, thr, momentum=0.7, lr=0.1)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    # elements exactly at the threshold may flip selection depending on FMA
    # ordering — exclude the boundary (measure-zero) set from comparison
    uacc = 0.7 * np.asarray(u, np.float32) + 0.1 * np.asarray(g, np.float32)
    interior = np.abs(np.abs(uacc) - 0.5) > 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32)[interior],
                               np.asarray(r_out, np.float32)[interior],
                               atol=tol)
    np.testing.assert_allclose(np.asarray(unew, np.float32)[interior],
                               np.asarray(r_unew, np.float32)[interior],
                               atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("r", [1, 4, 16])
def test_block_topk_contract_sweep(shape, r):
    key = jax.random.PRNGKey((hash(shape) + r) % 2 ** 31)
    x = jax.random.normal(key, shape)
    cv, ci = ops.block_topk_candidates(x, r=r)
    rv, ri = ref.block_topk_ref(x, block=1024, r=r)
    nb = rv.shape[0]
    np.testing.assert_allclose(np.asarray(cv[:nb]), np.asarray(rv),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ci[:nb]), np.asarray(ri))


@pytest.mark.parametrize("n,k", [(512, 16), (3000, 64), (8192, 128)])
def test_hierarchical_topk_exact_when_r_ge_k(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    v, i = ops.hierarchical_topk(x, k=k)  # r defaults to k -> exact
    rv, _ = jax.lax.top_k(jnp.abs(x), k)
    np.testing.assert_allclose(np.sort(np.abs(np.asarray(v)))[::-1],
                               np.asarray(rv), atol=1e-6)
    # indices point at the right values
    flat = np.asarray(x)
    for vi, ii in zip(np.asarray(v), np.asarray(i)):
        assert flat[ii] == vi


def test_hierarchical_topk_approx_quality():
    """Oversampled approximate mode recovers >=80% of true top-k mass on
    gaussian data."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1 << 16,))
    k = 655  # 1%
    v, _ = ops.hierarchical_topk(x, k=k, r=32)  # 64 blocks * 32 = 2048 cands
    true_mass = float(jnp.sum(jax.lax.top_k(jnp.abs(x), k)[0]))
    got_mass = float(jnp.sum(jnp.abs(v)))
    assert got_mass > 0.8 * true_mass


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 5000), st.floats(0.1, 0.95), st.integers(0, 2 ** 31))
def test_property_samomentum_kernel_vs_oracle(n, m, seed):
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    thr = jnp.float32(abs(float(jax.random.normal(
        jax.random.fold_in(key, 2), ()))))
    out, unew = ops.samomentum_fused(u, g, thr, momentum=m, lr=0.05)
    r_out, r_unew, _ = ref.samomentum_ref(u, g, thr, momentum=m, lr=0.05)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r_out), atol=1e-5)
    np.testing.assert_allclose(np.asarray(unew), np.asarray(r_unew),
                               atol=1e-5)


def test_scatter_accumulate_ref_duplicates():
    dense = jnp.zeros((8,))
    idx = jnp.asarray([1, 1, 3], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 4.0])
    out = ref.scatter_accumulate_ref(dense, idx, vals)
    np.testing.assert_allclose(out, [0, 3, 0, 4, 0, 0, 0, 0])


@pytest.mark.parametrize("n,k", [(1000, 10), (5000, 200), (8192, 64)])
def test_scatter_apply_sweep(n, k):
    key = jax.random.PRNGKey(n + k)
    dense = jax.random.normal(key, (n,))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (k,), 0,
                             n).astype(jnp.int32)
    vals = jax.random.normal(jax.random.fold_in(key, 2), (k,))
    out = ops.scatter_apply(dense, idx, vals)
    exp = ref.scatter_accumulate_ref(dense, idx, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_scatter_apply_duplicates_and_cap():
    dense = jnp.zeros((4096,))
    idx = jnp.asarray([5, 5, 5, 5, 2100], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 7.0])
    out = ops.scatter_apply(dense, idx, vals, cap=2)  # cap forces spill path
    exp = ref.scatter_accumulate_ref(dense, idx, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


# ------------------------------------------------ multi-row scatter (rows)

@pytest.mark.parametrize("n,n_rows,k", [(5000, 3, 40), (2048, 4, 64),
                                        (700, 2, 13)])
def test_scatter_add_rows_matches_row_loop(n, n_rows, k):
    """One fused multi-row scatter == any serial order of per-row
    scatters (disjoint rows), bit for bit — the batched commit contract."""
    rng = np.random.default_rng(n + k)
    dense = jnp.asarray(rng.normal(size=(n_rows + 2, n)).astype(np.float32))
    rows = jnp.asarray(rng.permutation(n_rows + 2)[:n_rows].astype(np.int32))
    idx = jnp.asarray(rng.integers(0, n, (n_rows, k)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(n_rows, k)).astype(np.float32))
    out = ops.scatter_add_rows(dense, rows, idx, vals)
    expect = dense
    for b in range(n_rows):
        expect = ops.scatter_add_row(expect, rows[b], idx[b], vals[b])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_scatter_apply_rows_interpret_matches_xla():
    """The blocked Pallas rows kernel (interpret mode) against the plain
    XLA scatter, duplicates included."""
    rng = np.random.default_rng(7)
    n_rows, n, k = 3, 5000, 120
    dense = jnp.asarray(rng.normal(size=(n_rows, n)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, (n_rows, k)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(n_rows, k)).astype(np.float32))
    out = ops.scatter_apply_rows(dense, idx, vals, interpret=True)
    expect = jnp.stack([dense[b].at[idx[b]].add(vals[b])
                        for b in range(n_rows)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)


def test_scatter_apply_rows_cap_spill():
    """cap smaller than the densest block: overflow updates must still be
    applied exactly (via the XLA spill), not dropped."""
    n_rows, n = 2, 4096
    idx = jnp.asarray(np.stack([np.full(32, 5, np.int32),
                                np.full(32, 4000, np.int32)]))
    vals = jnp.ones((n_rows, 32), jnp.float32)
    dense = jnp.zeros((n_rows, n), jnp.float32)
    out = ops.scatter_apply_rows(dense, idx, vals, cap=4, interpret=True)
    expect = jnp.stack([dense[b].at[idx[b]].add(vals[b])
                        for b in range(n_rows)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)
