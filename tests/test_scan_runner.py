"""The jitted scan runner must match the python event loop EXACTLY:
losses, final params, and up/down byte totals, bit for bit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_sim, make_strategy
from repro.core.engine import CompressionSpec
from repro.core.paramspace import ParamSpace
from repro.core.scan_runner import run_async_scan


def _problem():
    key = jax.random.PRNGKey(0)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"]) ** 2 - 2 * jnp.mean(
                (x @ p["w"] + p["b"]) * y))

        return jax.value_and_grad(loss)(params)

    def batch(e, k):
        kk = jax.random.PRNGKey(e * 131 + k + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    return grad_fn, batch


def _run_both(name, kw, *, sd=None, spec=CompressionSpec(engine="exact"),
              n_events=40, n_workers=3):
    grad_fn, batch_fn = _problem()
    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    sched = async_sim.make_schedule(n_workers, n_events, seed=7, hetero=0.9)
    strategy = make_strategy(name, **kw)
    tr = async_sim.AsyncTrainer(strategy, grad_fn, n_workers, lr=0.03,
                                secondary_density=sd, secondary_spec=spec)
    f_py, _, h_py = tr.run(params0, sched,
                           lambda e, k: batch_fn(e, int(k)))
    batches = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    f_scan, h_scan = run_async_scan(strategy, grad_fn, params0, sched,
                                    stacked, n_workers=n_workers, lr=0.03,
                                    secondary_density=sd,
                                    secondary_spec=spec)
    return f_py, h_py, f_scan, h_scan


@pytest.mark.parametrize("name,kw", [
    ("asgd", {}),
    ("dgs", {"density": 0.2, "momentum": 0.7}),
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}),
    ("gd_async", {"density": 0.2}),
])
def test_scan_matches_python_loop_bitforbit(name, kw):
    f_py, h_py, f_scan, h_scan = _run_both(name, kw)
    for a, b in zip(jax.tree.leaves(f_py), jax.tree.leaves(f_scan)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(h_py.losses, np.asarray(h_scan.losses))
    np.testing.assert_array_equal(h_py.staleness, h_scan.staleness)
    assert h_py.up_bytes == h_scan.up_bytes
    assert h_py.down_bytes == h_scan.down_bytes


@pytest.mark.parametrize("name,kw,sd,spec", [
    # dense down: data-dependent DENSE/DENSE_COO framing per event
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}, None,
     CompressionSpec(engine="exact")),
    # secondary compression + int8 wire both ways: static arena frames
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}, 0.1,
     CompressionSpec(engine="exact", quantize="int8")),
    # tern up, bf16 secondary
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "tern"}, 0.1,
     CompressionSpec(engine="exact", quantize="bf16")),
    # dense up (ASGD): data-dependent up framing
    ("asgd", {}, 0.1, CompressionSpec(engine="exact")),
])
def test_scan_byte_parity(name, kw, sd, spec):
    """up_bytes/down_bytes must agree with the python loop exactly — the
    scan's static (and vectorized-dense) accounting IS the codec's
    measured frame size."""
    _, h_py, _, h_scan = _run_both(name, kw, sd=sd, spec=spec)
    assert h_py.up_bytes == h_scan.up_bytes
    assert h_py.down_bytes == h_scan.down_bytes
    assert h_scan.up_bytes > 0 and h_scan.down_bytes > 0


def test_quantized_dgs_converges_and_saves_bytes():
    grad_fn, batch_fn = _problem()
    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    sched = async_sim.make_schedule(4, 250, seed=1, hetero=0.5)
    results = {}
    for q in ("none", "tern"):
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.2, momentum=0.5, quantize=q),
            grad_fn, 4, lr=0.05)
        _, _, hist = tr.run(params0, sched,
                            lambda e, k: batch_fn(e, int(k)))
        results[q] = hist
    # both converge
    for q, h in results.items():
        assert h.losses[-10:].mean() < h.losses[:10].mean(), q
    # byte accounting IS the wire codec's serialized ARENA frame size:
    # check it exactly against the codec's formula for this fixed shape
    from repro.cluster import wire
    space = ParamSpace.from_tree(params0)
    seg = space.ks(0.2)   # (1, 5): density 0.2 of b (4,) and w (6,4)
    assert seg == (1, 5)
    n_events = 250
    for q, h in results.items():
        per_event = wire.frame_bytes_static(seg, space.total, q)
        assert h.up_bytes == n_events * per_event, q
    assert results["tern"].up_bytes < results["none"].up_bytes
