"""The jitted scan runner must match the python event loop exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_sim, make_strategy
from repro.core.scan_runner import run_async_scan


def _problem():
    key = jax.random.PRNGKey(0)
    Wt = jax.random.normal(key, (6, 4))

    def grad_fn(params, batch):
        x, y = batch

        def loss(p):
            return jnp.mean((x @ p["w"] + p["b"]) ** 2 - 2 * jnp.mean(
                (x @ p["w"] + p["b"]) * y))

        return jax.value_and_grad(loss)(params)

    def batch(e, k):
        kk = jax.random.PRNGKey(e * 131 + k + 1)
        x = jax.random.normal(kk, (8, 6))
        return x, x @ Wt

    return grad_fn, batch


@pytest.mark.parametrize("name,kw", [
    ("asgd", {}),
    ("dgs", {"density": 0.2, "momentum": 0.7}),
    ("dgs", {"density": 0.2, "momentum": 0.7, "quantize": "int8"}),
    ("gd_async", {"density": 0.2}),
])
def test_scan_matches_python_loop(name, kw):
    grad_fn, batch_fn = _problem()
    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    n_events, n_workers = 40, 3
    sched = async_sim.make_schedule(n_workers, n_events, seed=7, hetero=0.9)
    strategy = make_strategy(name, **kw)
    # python loop
    tr = async_sim.AsyncTrainer(strategy, grad_fn, n_workers, lr=0.03)
    f_py, _, hist = tr.run(params0, sched,
                           lambda e, k: batch_fn(e, int(k)))
    # jitted scan (same batches, stacked)
    batches = [batch_fn(e, int(sched[e])) for e in range(n_events)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    f_scan, losses = run_async_scan(strategy, grad_fn, params0, sched,
                                    stacked, n_workers=n_workers, lr=0.03)
    for a, b in zip(jax.tree.leaves(f_py), jax.tree.leaves(f_scan)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(hist.losses, np.asarray(losses), atol=1e-5)


def test_quantized_dgs_converges_and_saves_bytes():
    grad_fn, batch_fn = _problem()
    params0 = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}
    sched = async_sim.make_schedule(4, 250, seed=1, hetero=0.5)
    results = {}
    for q in ("none", "tern"):
        tr = async_sim.AsyncTrainer(
            make_strategy("dgs", density=0.2, momentum=0.5, quantize=q),
            grad_fn, 4, lr=0.05)
        _, _, hist = tr.run(params0, sched,
                            lambda e, k: batch_fn(e, int(k)))
        results[q] = hist
    # both converge
    for q, h in results.items():
        assert h.losses[-10:].mean() < h.losses[:10].mean(), q
    # byte accounting IS the wire codec's serialized frame size: check it
    # exactly against the codec's per-leaf formula for this fixed shape
    from repro.cluster import wire
    n_events = 250
    ks = {"w": (5, 24), "b": (1, 4)}  # density 0.2 of (6,4) and (4,)
    for q, h in results.items():
        per_event = 17 + sum(wire.leaf_frame_bytes(k, n, q)
                             for k, n in ks.values())
        assert h.up_bytes == n_events * per_event, q
    assert results["tern"].up_bytes < results["none"].up_bytes
